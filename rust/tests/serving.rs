//! Snapshot/restore and serving-mode integration contracts:
//!
//! 1. property: checkpointing after a random number of steps and
//!    restoring into a **fresh** process-local `Simulator` reproduces
//!    the continuous run bit-for-bit — spike trains exactly, counters
//!    exactly on the serial driver and up to the scheduling-observable
//!    fields on the threaded drivers — across every schedule
//!    (serial, static, pipelined, adaptive) × d_min ∈ {1, 5};
//! 2. the restored engine and the original continue identically
//!    (restore is a faithful fork, not just a replay);
//! 3. end-to-end serving smoke through the public API only: a
//!    `SessionServer` session's streamed batches reconstruct the
//!    direct `simulate()` run, losslessly under the blocking policy.

use nsim::engine::{snapshot, Counters, Decomposition, SimConfig, Simulator};
use nsim::models::{IafParams, ModelKind, RESOLUTION_MS};
use nsim::network::rules::{weight_dist, ConnRule};
use nsim::network::{build, Dist, NetworkSpec};
use nsim::runtime::serving::{BackpressurePolicy, SessionConfig, SessionServer};
use nsim::util::prop::{check, Gen};

/// A balanced network with exact-multiple-of-h delays: d_min = 5 steps
/// (0.5 ms), d_max = 15 steps — the interval cycle batches 5 update
/// steps per communication round (mirrors `tests/determinism.rs`;
/// integration tests cannot reach the crate-private spec helpers).
fn interval_spec(seed: u64) -> NetworkSpec {
    let v0 = Dist::ClippedNormal {
        mean: -58.0,
        std: 5.0,
        lo: f64::NEG_INFINITY,
        hi: -50.000001,
    };
    let mut s = NetworkSpec::new(RESOLUTION_MS, seed);
    let e = s.add_population(
        "E",
        240,
        ModelKind::IafPscExp,
        IafParams::default(),
        v0,
        10_000.0,
        87.8,
    );
    let i = s.add_population(
        "I",
        60,
        ModelKind::IafPscExp,
        IafParams::default(),
        v0,
        10_000.0,
        87.8,
    );
    s.connect(
        e,
        e,
        ConnRule::FixedTotalNumber { n: 2400 },
        weight_dist(87.8, 0.1),
        Dist::Const(0.5), // 5 steps = d_min
    );
    s.connect(
        e,
        i,
        ConnRule::FixedTotalNumber { n: 600 },
        weight_dist(87.8, 0.1),
        Dist::Const(1.5), // 15 steps = d_max
    );
    s.connect(
        i,
        e,
        ConnRule::FixedTotalNumber { n: 600 },
        weight_dist(-351.2, 0.1),
        Dist::Const(0.8), // 8 steps: arrivals cross interval boundaries
    );
    s
}

/// `interval_spec` with every delay forced to h (0.1 ms): d_min = 1
/// step, the paper's per-step exchange pattern.
fn dmin1_spec(seed: u64) -> NetworkSpec {
    let mut s = interval_spec(seed);
    for proj in s.projections.iter_mut() {
        proj.delay = Dist::Const(0.1);
    }
    s
}

/// The schedule axis of the checkpoint property: (name, OS threads,
/// pipelined, adaptive). `serial` is the 1-thread reference driver; the
/// other three are the threaded-driver schedules.
const SCHEDULES: [(&str, usize, bool, bool); 4] = [
    ("serial", 1, false, false),
    ("static", 4, false, false),
    ("pipelined", 4, true, false),
    ("adaptive", 4, true, true),
];

fn sim_for(spec: &NetworkSpec, os_threads: usize, pipelined: bool, adaptive: bool) -> Simulator {
    let d = Decomposition::new(1, 6); // 6 VPs on ≤ 4 threads: non-divisible partition
    Simulator::new(
        build(spec, d),
        SimConfig {
            record_spikes: true,
            os_threads,
            pipelined,
            adaptive,
            vectorize: true,
        },
    )
}

/// Zero the counter fields that are scheduling-observable rather than
/// model-determined: the local/stolen task split depends on thread
/// racing, and the adaptive merge-slice bounds reset per `simulate()`
/// call, so a split run legitimately differs from a continuous one in
/// exactly these four fields (their conserved totals are covered by the
/// remaining counters).
fn scrub(mut c: Counters) -> Counters {
    c.deliver_tasks_local = 0;
    c.deliver_tasks_stolen = 0;
    c.merge_slice_max_packets = 0;
    c.merge_slice_min_packets = 0;
    c
}

#[test]
fn prop_checkpoint_restore_bit_identical_across_schedules() {
    const T_STEPS: u64 = 600; // 60 ms
    check(
        0x5e55,
        2,
        |g: &mut Gen| {
            let seed = g.rng.next_u64();
            // random checkpoint step in [1, T): interval-misaligned cuts
            // (pending > 0 in the snapshot) included deliberately
            let k = g.size(1, (T_STEPS - 1) as usize) as u64;
            (seed, k)
        },
        |&(seed, k)| {
            let t_cut = k as f64 * RESOLUTION_MS;
            let t_rest = (T_STEPS - k) as f64 * RESOLUTION_MS;
            for (dmin_name, spec) in [
                ("d_min=1", dmin1_spec(seed)),
                ("d_min=5", interval_spec(seed)),
            ] {
                for (sched, os_threads, pipelined, adaptive) in SCHEDULES {
                    let tag = format!("{dmin_name}/{sched} @ step {k}");
                    let serial = os_threads == 1;

                    let mut cont = sim_for(&spec, os_threads, pipelined, adaptive);
                    let r_cont = cont.simulate(T_STEPS as f64 * RESOLUTION_MS);

                    let mut orig = sim_for(&spec, os_threads, pipelined, adaptive);
                    let r_head = orig.simulate(t_cut);
                    let bytes = orig.snapshot();
                    let mut fresh = sim_for(&spec, os_threads, pipelined, adaptive);
                    fresh
                        .restore(&bytes)
                        .map_err(|e| format!("{tag}: restore failed: {e}"))?;
                    if fresh.now_step() != k {
                        return Err(format!("{tag}: restored clock at {}", fresh.now_step()));
                    }
                    let r_tail = fresh.simulate(t_rest);

                    // spikes: head + tail must equal the continuous run
                    let mut joined = r_head.spikes.clone();
                    joined.extend_from_slice(&r_tail.spikes);
                    if joined != r_cont.spikes {
                        return Err(format!(
                            "{tag}: split spikes diverged ({} vs {})",
                            joined.len(),
                            r_cont.spikes.len()
                        ));
                    }

                    // counters: summed head + tail must equal continuous —
                    // exactly on the serial driver, modulo the four
                    // scheduling-observable fields on the threaded ones
                    let mut summed = r_head.counters;
                    summed.add(&r_tail.counters);
                    let (a, b) = if serial {
                        (summed, r_cont.counters)
                    } else {
                        (scrub(summed), scrub(r_cont.counters))
                    };
                    if a != b {
                        return Err(format!("{tag}: counters diverged\n{a:#?}\nvs\n{b:#?}"));
                    }

                    // the restored fork and the original continue identically
                    let r_orig_tail = orig.simulate(t_rest);
                    if r_tail.spikes != r_orig_tail.spikes {
                        return Err(format!("{tag}: fork diverged from original"));
                    }
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn checkpoint_file_roundtrip_restores_the_clock_and_spikes() {
    let spec = interval_spec(0xf11e);
    let dir = std::env::temp_dir().join(format!("nsim-serving-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.snap");

    let mut orig = sim_for(&spec, 1, false, false);
    orig.simulate(20.0);
    snapshot::save_to_file(&orig, &path).unwrap();
    let r_orig = orig.simulate(40.0);

    let mut fresh = sim_for(&spec, 1, false, false);
    snapshot::restore_from_file(&mut fresh, &path).unwrap();
    assert_eq!(fresh.now_step(), 200);
    let r_rest = fresh.simulate(40.0);
    assert_eq!(r_rest.spikes, r_orig.spikes);
    assert_eq!(r_rest.counters, r_orig.counters);
    assert!(!r_rest.spikes.is_empty(), "restored run must be active");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serving_session_streams_the_direct_run_losslessly() {
    let spec = interval_spec(0x5e7e);
    let mut direct = sim_for(&spec, 2, true, true);
    let reference = direct.simulate(30.0).spikes;
    assert!(!reference.is_empty(), "reference run must be active");

    let mut srv = SessionServer::new();
    let (id, stream) = srv.open(
        sim_for(&spec, 2, true, true),
        30.0,
        SessionConfig {
            capacity: 8,
            policy: BackpressurePolicy::Block,
            ..Default::default()
        },
    );
    let consumer = std::thread::spawn(move || {
        let mut records = Vec::new();
        while let Some(b) = stream.recv() {
            records.extend(b.records());
        }
        records
    });
    let ticks = srv.run_until_idle();
    let streamed = consumer.join().unwrap();

    assert_eq!(streamed, reference, "streamed batches must rebuild the run");
    let st = srv.stats(id).unwrap();
    assert!(st.done);
    assert_eq!(st.batches_dropped, 0, "blocking policy must be lossless");
    assert_eq!(st.intervals_served, ticks);
    assert_eq!(st.intervals_served, 60); // 300 steps / 5-step interval
    assert_eq!(st.spikes_streamed as usize, reference.len());
}
