//! Three-layer integration: the AOT artifact (L2 JAX model calling the
//! L1 Pallas kernel) executed from rust via PJRT must reproduce the
//! native engine's dynamics. Requires `make artifacts` (skipped with a
//! message otherwise).

use nsim::engine::backend::{NativeBackend, NeuronBackend};
use nsim::engine::{Decomposition, SimConfig, Simulator};
use nsim::models::{IafParams, IafPscExp, ModelKind, NeuronState, RESOLUTION_MS};
use nsim::network::rules::{delay_dist, weight_dist, ConnRule};
use nsim::network::{build, Dist, NetworkSpec};
use nsim::runtime::{param_vec, XlaBackend, XlaRuntime};
use nsim::util::rng::Pcg64;

const DIR: &str = "artifacts";
const BATCH: usize = 1024;

fn artifacts_present() -> bool {
    std::path::Path::new(&format!("{DIR}/lif_step_b{BATCH}.hlo.txt")).exists()
}

macro_rules! require_artifacts {
    () => {
        if !cfg!(feature = "xla") {
            eprintln!("SKIP: built without the `xla` feature (stub runtime)");
            return;
        }
        if !artifacts_present() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn artifact_loads_and_runs() {
    require_artifacts!();
    let rt = XlaRuntime::load_default(DIR, BATCH, true).unwrap();
    let model = IafPscExp::new(&IafParams::default(), RESOLUTION_MS);
    let params = param_vec(&model);
    let zero = vec![0.0; BATCH];
    let one_input = {
        let mut v = vec![0.0; BATCH];
        v[0] = 87.8;
        v
    };
    let refr = vec![0.0; BATCH];
    let out = rt
        .step(&zero, &zero, &zero, &refr, &one_input, &zero, &params)
        .unwrap();
    // current injected, voltage unchanged this step
    assert_eq!(out[0][0], 0.0);
    assert_eq!(out[1][0], 87.8);
    assert!(out[4].iter().all(|&s| s == 0.0));
}

#[test]
fn xla_step_matches_native_model_stepwise() {
    require_artifacts!();
    let rt = XlaRuntime::load_default(DIR, BATCH, true).unwrap();
    let model = IafPscExp::new(
        &IafParams {
            i_e: 420.0,
            ..Default::default()
        },
        RESOLUTION_MS,
    );
    let params = param_vec(&model);
    let mut rng = Pcg64::seed_from_u64(99);

    // native state
    let mut st = NeuronState::with_len(BATCH);
    for i in 0..BATCH {
        st.v_m[i] = rng.uniform() * 30.0 - 15.0;
        st.i_ex[i] = rng.uniform() * 200.0;
        st.i_in[i] = -rng.uniform() * 200.0;
        st.refr[i] = (rng.below(3)) as u32;
    }
    // xla state mirrors it
    let mut v = st.v_m.to_vec();
    let mut iex = st.i_ex.to_vec();
    let mut iin = st.i_in.to_vec();
    let mut refr: Vec<f64> = st.refr.iter().map(|&r| r as f64).collect();

    let mut native_spikes = 0u64;
    let mut xla_spikes = 0u64;
    for _ in 0..100 {
        let in_ex: Vec<f64> = (0..BATCH).map(|_| rng.uniform() * 50.0).collect();
        let in_in: Vec<f64> = (0..BATCH).map(|_| -rng.uniform() * 25.0).collect();
        let mut spikes = Vec::new();
        native_spikes +=
            model.update_chunk(&mut st, 0, BATCH, &in_ex, &in_in, &mut spikes) as u64;
        let out = rt
            .step(&v, &iex, &iin, &refr, &in_ex, &in_in, &params)
            .unwrap();
        v = out[0].clone();
        iex = out[1].clone();
        iin = out[2].clone();
        refr = out[3].clone();
        xla_spikes += out[4].iter().filter(|&&s| s != 0.0).count() as u64;

        for i in 0..BATCH {
            assert!(
                (st.v_m[i] - v[i]).abs() < 1e-9,
                "v diverged at lane {i}: {} vs {}",
                st.v_m[i],
                v[i]
            );
            assert!((st.i_ex[i] - iex[i]).abs() < 1e-9);
            assert!((st.i_in[i] - iin[i]).abs() < 1e-9);
            assert_eq!(st.refr[i] as f64, refr[i], "refr lane {i}");
        }
    }
    assert_eq!(native_spikes, xla_spikes);
    assert!(native_spikes > 0, "DC drive must spike within 10 ms");
}

fn tiny_net(seed: u64) -> NetworkSpec {
    let mut s = NetworkSpec::new(RESOLUTION_MS, seed);
    let v0 = Dist::ClippedNormal {
        mean: -58.0,
        std: 5.0,
        lo: f64::NEG_INFINITY,
        hi: -50.000001,
    };
    let e = s.add_population(
        "E",
        160,
        ModelKind::IafPscExp,
        IafParams::default(),
        v0,
        10_000.0,
        87.8,
    );
    let i = s.add_population(
        "I",
        40,
        ModelKind::IafPscExp,
        IafParams::default(),
        v0,
        10_000.0,
        87.8,
    );
    s.connect(
        e,
        i,
        ConnRule::FixedTotalNumber { n: 400 },
        weight_dist(87.8, 0.1),
        delay_dist(1.5, 0.75, RESOLUTION_MS),
    );
    s.connect(
        i,
        e,
        ConnRule::FixedTotalNumber { n: 400 },
        weight_dist(-351.2, 0.1),
        delay_dist(0.75, 0.375, RESOLUTION_MS),
    );
    s
}

#[test]
fn full_engine_identical_spike_trains_native_vs_xla() {
    require_artifacts!();
    let run = |xla: bool| {
        let net = build(&tiny_net(21), Decomposition::serial());
        let cfg = SimConfig {
            record_spikes: true,
            os_threads: 1,
            pipelined: true,
            adaptive: true,
            vectorize: true,
        };
        let mut sim = if xla {
            let be = XlaBackend::from_artifacts(DIR, BATCH, true).unwrap();
            Simulator::with_backend(net, cfg, Box::new(be)).expect("iaf_psc_exp spec")
        } else {
            Simulator::with_backend(net, cfg, Box::new(NativeBackend::default()))
                .expect("iaf_psc_exp spec")
        };
        sim.simulate(200.0)
    };
    let native = run(false);
    let xla = run(true);
    assert!(!native.spikes.is_empty(), "network must be active");
    assert_eq!(
        native.spikes, xla.spikes,
        "three-layer stack must reproduce native spike trains"
    );
    assert_eq!(
        native.counters.syn_events_delivered,
        xla.counters.syn_events_delivered
    );
}

#[test]
fn jnp_fallback_artifact_agrees_with_pallas_artifact() {
    require_artifacts!();
    let rt_pallas = XlaRuntime::load_default(DIR, BATCH, true).unwrap();
    let rt_jnp = XlaRuntime::load_default(DIR, BATCH, false).unwrap();
    let model = IafPscExp::new(&IafParams::default(), RESOLUTION_MS);
    let params = param_vec(&model);
    let mut rng = Pcg64::seed_from_u64(5);
    let mk = |f: &mut dyn FnMut() -> f64| -> Vec<f64> { (0..BATCH).map(|_| f()).collect() };
    let v = mk(&mut || rng.uniform() * 20.0 - 10.0);
    let iex = mk(&mut || rng.uniform() * 300.0);
    let iin = mk(&mut || -rng.uniform() * 300.0);
    let refr = mk(&mut || rng.below(3) as f64);
    let inex = mk(&mut || rng.uniform() * 80.0);
    let inin = mk(&mut || -rng.uniform() * 40.0);
    let a = rt_pallas
        .step(&v, &iex, &iin, &refr, &inex, &inin, &params)
        .unwrap();
    let b = rt_jnp
        .step(&v, &iex, &iin, &refr, &inex, &inin, &params)
        .unwrap();
    for k in 0..5 {
        for i in 0..BATCH {
            assert!(
                (a[k][i] - b[k][i]).abs() < 1e-12,
                "output {k} lane {i}: {} vs {}",
                a[k][i],
                b[k][i]
            );
        }
    }
}
