//! Binary-level chaos tests of the fault-tolerant mesh: seeded fault
//! plans injected into real runs must either complete with a spike
//! train bit-identical to a clean run (the reliability protocol
//! absorbs drops, duplicates, corruption and delays) or fail fast with
//! a typed error inside the configured deadline — never hang, and
//! never record a corrupted train. Rank death plus `--auto-checkpoint`
//! must recover through the parent's checkpoint-restart supervision,
//! again bit-identically.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{Duration, Instant};

fn nsim_bin() -> &'static str {
    env!("CARGO_BIN_EXE_nsim")
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nsim_ft_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// `nsim simulate` with the suite's fixed workload (scale 0.02,
/// 100 ms model + 20 ms presim, seed 55374) writing `spikes_out`;
/// returns captured stdout for assertions on the supervision log.
fn run_simulate(extra: &[&str], spikes_out: &Path) -> String {
    let mut cmd = Command::new(nsim_bin());
    cmd.args([
        "simulate",
        "--scale",
        "0.02",
        "--t-model",
        "100",
        "--t-presim",
        "20",
        "--seed",
        "55374",
        "--os-threads",
        "2",
        "--spikes-out",
    ])
    .arg(spikes_out)
    .args(extra);
    let out = cmd.output().expect("spawn nsim");
    assert!(
        out.status.success(),
        "nsim simulate {extra:?} failed:\nstdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// S3 property suite, in-process leg: randomised-but-seeded fault
/// plans over the 2-rank loopback mesh never change the recorded
/// train. Each plan exercises drops (retry), duplicates (dedup),
/// short delays and one corrupted frame (checksum reject + resend).
#[test]
fn seeded_fault_plans_leave_loopback_train_bit_identical() {
    let dir = scratch_dir("loopback");
    let clean = dir.join("clean.csv");
    run_simulate(&["--ranks", "2", "--threads", "2"], &clean);
    let want = std::fs::read(&clean).expect("read clean dump");
    assert!(!want.is_empty(), "clean run recorded no spikes");

    for seed in [11u64, 12, 13] {
        let plan = format!("seed={seed},drop=0.35,dup=0.25,delay=0.05:2,corrupt={}", seed % 40);
        let injected = dir.join(format!("plan{seed}.csv"));
        run_simulate(
            &["--ranks", "2", "--threads", "2", "--fault-plan", &plan],
            &injected,
        );
        let got = std::fs::read(&injected).expect("read injected dump");
        assert_eq!(got, want, "plan '{plan}' changed the recorded train");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// S3 property suite, multi-process leg: a chaos plan (drops,
/// duplicates, delays, one corrupted frame, one stalled round) over a
/// real 2-process TCP mesh with a per-round deadline completes with
/// the clean train, bit for bit. On Linux the same plan also runs over
/// the shared-memory rings.
#[test]
fn chaos_plan_over_process_meshes_matches_clean_run() {
    let dir = scratch_dir("chaos");
    let clean = dir.join("clean.csv");
    run_simulate(&["--ranks", "2", "--threads", "2"], &clean);
    let want = std::fs::read(&clean).expect("read clean dump");

    let plan = "seed=7,drop=0.3,dup=0.2,delay=0.1:2,corrupt=12,stall=30:200";
    let tcp = dir.join("tcp.csv");
    run_simulate(
        &[
            "--ranks",
            "2",
            "--threads",
            "2",
            "--transport",
            "tcp",
            "--fault-plan",
            plan,
            "--round-deadline-ms",
            "10000",
        ],
        &tcp,
    );
    let got = std::fs::read(&tcp).expect("read tcp dump");
    assert_eq!(got, want, "chaos tcp mesh diverged from the clean run");

    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    {
        let shm = dir.join("shm.csv");
        run_simulate(
            &[
                "--ranks",
                "2",
                "--threads",
                "2",
                "--transport",
                "shm",
                "--fault-plan",
                plan,
                "--round-deadline-ms",
                "10000",
            ],
            &shm,
        );
        let got = std::fs::read(&shm).expect("read shm dump");
        assert_eq!(got, want, "chaos shm mesh diverged from the clean run");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A rank killed mid-run with `--auto-checkpoint` active must be
/// recovered by the parent: mesh torn down, restarted from the newest
/// checkpoint every rank committed, and the final train bit-identical
/// to a run that never failed.
#[test]
fn killed_rank_recovers_from_checkpoint_bit_identically() {
    let dir = scratch_dir("recover");
    let clean = dir.join("clean.csv");
    run_simulate(&["--ranks", "2", "--threads", "2"], &clean);
    let want = std::fs::read(&clean).expect("read clean dump");

    let recovered = dir.join("recovered.csv");
    let stdout = run_simulate(
        &[
            "--ranks",
            "2",
            "--threads",
            "2",
            "--transport",
            "tcp",
            "--fault-plan",
            "seed=5,drop=0.1,kill=1:60",
            "--auto-checkpoint",
            "8",
            "--round-deadline-ms",
            "5000",
            "--max-restarts",
            "2",
        ],
        &recovered,
    );
    assert!(
        stdout.contains("restarting mesh"),
        "supervisor must report the restart, stdout:\n{stdout}"
    );
    let got = std::fs::read(&recovered).expect("read recovered dump");
    assert_eq!(got, want, "recovered run diverged from the clean run");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A permanently dead peer must surface as a typed transport error on
/// the surviving rank within the configured round deadline — not as a
/// hang. Two workers are driven directly (no supervising parent, so
/// nothing reaps the survivor early): rank 1's plan kills it at round
/// 10; rank 0 must exit non-zero on its own with a peer-lost or
/// deadline error.
#[test]
fn dead_peer_surfaces_typed_error_within_deadline() {
    let dir = scratch_dir("peerlost");
    let rdv = dir.join("rdv");
    std::fs::create_dir_all(&rdv).expect("create rendezvous dir");
    let worker = |rank: usize, plan: Option<&str>| {
        let mut c = Command::new(nsim_bin());
        c.args([
            "__worker",
            "--rank",
            &rank.to_string(),
            "--ranks",
            "2",
            "--transport",
            "tcp",
            "--scale",
            "0.02",
            "--t-model",
            "100",
            "--t-presim",
            "20",
            "--seed",
            "55374",
            "--threads",
            "2",
            "--os-threads",
            "2",
        ])
        .arg("--rendezvous")
        .arg(&rdv)
        .arg("--summary")
        .arg(dir.join(format!("r{rank}.json")))
        .arg("--spikes")
        .arg(dir.join(format!("r{rank}.csv")))
        .env("NSIM_ROUND_DEADLINE_MS", "2000")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped());
        if let Some(p) = plan {
            c.arg("--fault-plan").arg(p);
        }
        c
    };
    let t0 = Instant::now();
    let survivor = worker(0, None).spawn().expect("spawn rank 0");
    let killed = worker(1, Some("seed=3,kill=1:10")).spawn().expect("spawn rank 1");
    let killed_out = killed.wait_with_output().expect("wait for rank 1");
    let surv_out = survivor.wait_with_output().expect("wait for rank 0");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "dead peer took {:?} to surface (deadline is 2 s)",
        t0.elapsed()
    );
    assert!(!killed_out.status.success(), "rank 1 must die on its kill round");
    assert!(!surv_out.status.success(), "rank 0 must fail, not hang");
    let err = String::from_utf8_lossy(&surv_out.stderr);
    assert!(
        err.contains("peer rank 1 lost") || err.contains("deadline expired"),
        "rank 0 must report a typed peer-lost/timeout error, stderr:\n{err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// S2: restoring from a snapshot path that does not exist is a typed
/// non-zero exit with a readable message, not a panic.
#[test]
fn checkpoint_restore_from_missing_snapshot_fails_cleanly() {
    let missing = std::env::temp_dir().join(format!("nsim_ft_missing_{}.snap", std::process::id()));
    let out = Command::new(nsim_bin())
        .args(["checkpoint", "--t-model", "1"])
        .arg("--from")
        .arg(&missing)
        .output()
        .expect("spawn nsim");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("cannot restore"), "stderr: {err}");
    assert!(!err.contains("panicked"), "missing snapshot must not panic the CLI, stderr: {err}");
}

/// A malformed fault plan is rejected up front by the parent as a
/// usage error (exit 2), before any worker is spawned.
#[test]
fn malformed_fault_plan_is_a_usage_error() {
    let out = Command::new(nsim_bin())
        .args([
            "simulate",
            "--ranks",
            "2",
            "--transport",
            "tcp",
            "--fault-plan",
            "drop=1.5",
        ])
        .output()
        .expect("spawn nsim");
    assert_eq!(out.status.code(), Some(2), "usage errors exit 2");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fault plan"), "stderr: {err}");
}
