"""AOT export path: the HLO-text artifacts must be generated, parseable
and numerically equivalent to the in-process computation when executed
through the local PJRT CPU client (the same route the rust runtime
takes)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.aot import lower_step, to_hlo_text
from compile.kernels.lif_update import BLOCK
from compile.kernels.ref import N_PARAMS, lif_step_ref, microcircuit_params


def test_hlo_text_structure():
    text = lower_step(BLOCK, use_pallas=True)
    assert "ENTRY" in text and "HloModule" in text
    # 7 f64 inputs: params[9] + 6 state/input vectors
    assert f"f64[{BLOCK}]" in text
    assert f"f64[{N_PARAMS}]" in text


def test_jnp_and_pallas_artifacts_both_lower():
    a = lower_step(BLOCK, use_pallas=True)
    b = lower_step(BLOCK, use_pallas=False)
    assert "ENTRY" in a and "ENTRY" in b


def test_hlo_text_parse_roundtrip():
    # the text must parse back into an HLO module losslessly (id
    # reassignment is the point of the text interchange). The *numeric*
    # execution roundtrip of the artifact happens on the consumer side:
    # rust/tests/xla_backend.rs loads this very text via
    # HloModuleProto::from_text_file and cross-checks against the native
    # engine — the modern jaxlib PJRT client only accepts MLIR modules,
    # so the python side validates parseability.
    from jax._src.lib import xla_client as xc

    text = lower_step(BLOCK, use_pallas=True)
    mod = xc._xla.hlo_module_from_text(text)
    reparsed = mod.to_string()
    assert "ENTRY" in reparsed
    # all seven parameters and five outputs survive the roundtrip
    assert reparsed.count(f"f64[{BLOCK}]") >= 11
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 1000


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--batches", str(BLOCK)],
        check=True,
        cwd=repo_python,
        env=env,
    )
    names = sorted(os.listdir(out))
    assert f"lif_step_b{BLOCK}.hlo.txt" in names
    assert f"lif_step_jnp_b{BLOCK}.hlo.txt" in names
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["block"] == BLOCK
    assert manifest["n_params"] == N_PARAMS
    assert len(manifest["artifacts"]) == 2


def test_to_hlo_text_rejects_nothing_silently():
    # a trivially different function must produce different HLO
    import jax
    import jax.numpy as jnp

    f1 = jax.jit(lambda x: (x + 1.0,)).lower(jax.ShapeDtypeStruct((4,), jnp.float64))
    f2 = jax.jit(lambda x: (x * 2.0,)).lower(jax.ShapeDtypeStruct((4,), jnp.float64))
    assert to_hlo_text(f1) != to_hlo_text(f2)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
