"""L2 physics: the model semantics against closed-form LIF solutions —
the same oracles the rust engine's unit tests use, guaranteeing the two
implementations agree on the dynamics definition."""

import numpy as np
from numpy.testing import assert_allclose

from compile.kernels.lif_update import BLOCK
from compile.kernels.ref import microcircuit_params
from compile.model import multi_step, population_step, population_step_jnp

H = 0.1
PARAMS = microcircuit_params(h=H)


def zeros():
    z = np.zeros(BLOCK)
    return z.copy(), z.copy(), z.copy(), z.copy()


def test_subthreshold_psp_matches_closed_form():
    # single 87.8 pA excitatory input at step 0; compare V(t) on the grid
    tau_m, tau_s, c_m, w = 10.0, 0.5, 250.0, 87.8
    v, i_ex, i_in, refr = zeros()
    max_err = 0.0
    for k in range(300):
        in_ex = np.zeros(BLOCK)
        if k == 0:
            in_ex[:] = w
        v, i_ex, i_in, refr, spk = population_step(v, i_ex, i_in, refr, in_ex, np.zeros(BLOCK), PARAMS)
        t = k * H
        v_ref = (
            w * tau_s * tau_m / (c_m * (tau_m - tau_s))
            * (np.exp(-t / tau_m) - np.exp(-t / tau_s))
        )
        max_err = max(max_err, abs(float(np.asarray(v)[0]) - v_ref))
        assert not np.any(np.asarray(spk)), "PSP must stay subthreshold"
    assert max_err < 1e-12, f"exact integration err {max_err:e}"


def test_dc_drive_isi_matches_theory():
    # I_e = 500 pA: ISI = t_ref + tau_m ln(Vinf/(Vinf - theta))
    params = microcircuit_params(h=H, i_e=500.0)
    v, i_ex, i_in, refr = zeros()
    spike_steps = []
    for k in range(10_000):
        v, i_ex, i_in, refr, spk = population_step_jnp(
            v, i_ex, i_in, refr, np.zeros(BLOCK), np.zeros(BLOCK), params
        )
        if float(np.asarray(spk)[0]) > 0:
            spike_steps.append(k)
    v_inf = 500.0 * 10.0 / 250.0
    isi_theory = (2.0 + 10.0 * np.log(v_inf / (v_inf - 15.0))) / H
    isis = np.diff(spike_steps)
    assert len(isis) > 5
    assert np.all(np.abs(isis - isi_theory) <= 1.0), (isis[:5], isi_theory)


def test_refractory_holds_voltage():
    params = microcircuit_params(h=H)
    v, i_ex, i_in, refr = zeros()
    huge = np.full(BLOCK, 1e6)
    zero = np.zeros(BLOCK)
    # inject huge current: spike arrives on the next step's update
    v, i_ex, i_in, refr, spk = population_step(v, i_ex, i_in, refr, huge, zero, params)
    assert not np.any(np.asarray(spk))
    v, i_ex, i_in, refr, spk = population_step(v, i_ex, i_in, refr, zero, zero, params)
    assert np.all(np.asarray(spk) == 1.0)
    assert np.all(np.asarray(refr) == 20.0)
    # V stays at reset during refractoriness despite the residual current
    for _ in range(19):
        v, i_ex, i_in, refr, spk = population_step(v, i_ex, i_in, refr, zero, zero, params)
        assert np.all(np.asarray(v) == 0.0)  # v_reset rel. E_L
        assert not np.any(np.asarray(spk))


def test_multi_step_scan_equals_loop():
    rng = np.random.default_rng(5)
    v0 = rng.uniform(-10, 10, BLOCK)
    in_ex = rng.uniform(0, 100, BLOCK)
    in_in = rng.uniform(-50, 0, BLOCK)
    z = np.zeros(BLOCK)
    out_scan = multi_step(v0, z, z, z, in_ex, in_in, PARAMS, n_steps=50)
    v, i_ex, i_in, refr = v0.copy(), z.copy(), z.copy(), z.copy()
    spikes = np.zeros(BLOCK)
    for _ in range(50):
        v, i_ex, i_in, refr, spk = population_step_jnp(v, i_ex, i_in, refr, in_ex, in_in, PARAMS)
        spikes = spikes + np.asarray(spk)
    for a, b in zip(out_scan, (v, i_ex, i_in, refr, spikes)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-13, atol=1e-12)


def test_inhibition_hyperpolarizes():
    v, i_ex, i_in, refr = zeros()
    zero = np.zeros(BLOCK)
    inh = np.full(BLOCK, -351.2)
    for _ in range(50):
        v, i_ex, i_in, refr, spk = population_step(v, i_ex, i_in, refr, zero, inh, PARAMS)
    assert np.all(np.asarray(v) < 0.0)
