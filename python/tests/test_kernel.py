"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and state values; assert_allclose at double
precision (the kernel and the oracle must agree to the ULP level —
they perform the same FMA sequence)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.lif_update import BLOCK, lif_step_pallas, pad_to_block
from compile.kernels.ref import lif_step_ref, microcircuit_params

PARAMS = microcircuit_params()


def random_state(rng, n):
    v = rng.uniform(-20.0, 16.0, n)
    i_ex = rng.uniform(0.0, 500.0, n)
    i_in = rng.uniform(-800.0, 0.0, n)
    refr = rng.integers(0, 4, n).astype(np.float64)
    in_ex = rng.uniform(0.0, 200.0, n)
    in_in = rng.uniform(-200.0, 0.0, n)
    return v, i_ex, i_in, refr, in_ex, in_in


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref_random_states(blocks, seed):
    rng = np.random.default_rng(seed)
    n = blocks * BLOCK
    args = random_state(rng, n)
    out_k = lif_step_pallas(*args, PARAMS)
    out_r = lif_step_ref(*args, PARAMS)
    for k, r, name in zip(out_k, out_r, ["v", "i_ex", "i_in", "refr", "spk"]):
        assert_allclose(np.asarray(k), np.asarray(r), rtol=1e-13, atol=1e-12, err_msg=name)


@settings(max_examples=15, deadline=None)
@given(
    i_e=st.floats(min_value=0.0, max_value=600.0),
    tau_m=st.floats(min_value=5.0, max_value=30.0),
    t_ref=st.floats(min_value=0.1, max_value=5.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref_across_parameters(i_e, tau_m, t_ref, seed):
    params = microcircuit_params(i_e=i_e, tau_m=tau_m, t_ref=t_ref)
    rng = np.random.default_rng(seed)
    args = random_state(rng, BLOCK)
    out_k = lif_step_pallas(*args, params)
    out_r = lif_step_ref(*args, params)
    for k, r in zip(out_k, out_r):
        assert_allclose(np.asarray(k), np.asarray(r), rtol=1e-13, atol=1e-12)


def test_multi_step_trajectory_agreement():
    # 200 steps of coupled evolution must stay equal to fp tolerance
    rng = np.random.default_rng(7)
    state_k = random_state(rng, BLOCK)[:4]
    state_r = tuple(np.copy(x) for x in state_k)
    total_spikes_k = 0.0
    total_spikes_r = 0.0
    for step in range(200):
        in_ex = rng.uniform(0.0, 60.0, BLOCK)
        in_in = rng.uniform(-30.0, 0.0, BLOCK)
        *state_k, spk_k = lif_step_pallas(*state_k, in_ex, in_in, PARAMS)
        *state_r, spk_r = lif_step_ref(*state_r, in_ex, in_in, PARAMS)
        total_spikes_k += float(np.sum(np.asarray(spk_k)))
        total_spikes_r += float(np.sum(np.asarray(spk_r)))
        for k, r in zip(state_k, state_r):
            assert_allclose(np.asarray(k), np.asarray(r), rtol=1e-13, atol=1e-12)
    assert total_spikes_k == total_spikes_r
    assert total_spikes_k > 0, "drive must elicit spikes in 200 steps"


def test_padding_lanes_are_inert():
    n = BLOCK // 2
    rng = np.random.default_rng(3)
    v, i_ex, i_in, refr, in_ex, in_in = random_state(rng, n)
    vp = pad_to_block(v)
    assert vp.shape[0] == BLOCK
    out = lif_step_pallas(
        pad_to_block(v),
        pad_to_block(i_ex),
        pad_to_block(i_in),
        pad_to_block(refr, fill=1.0),
        pad_to_block(in_ex),
        pad_to_block(in_in),
        PARAMS,
    )
    spk = np.asarray(out[4])
    assert np.all(spk[n:] == 0.0), "padding lanes must never spike"
    # and the real lanes agree with the unpadded oracle
    out_r = lif_step_ref(v, i_ex, i_in, refr, in_ex, in_in, PARAMS)
    for k, r in zip(out, out_r):
        assert_allclose(np.asarray(k)[:n], np.asarray(r), rtol=1e-13, atol=1e-12)


def test_rejects_unpadded_batch():
    rng = np.random.default_rng(1)
    args = random_state(rng, BLOCK + 3)
    with pytest.raises(AssertionError):
        lif_step_pallas(*args, PARAMS)
