"""Layer-1 Pallas kernels: the neuron-update hot loop.

``lif_update.lif_step_pallas`` is the production kernel (lowered with
``interpret=True`` so the emitted HLO runs on any PJRT backend, incl. the
rust CPU client); ``ref.lif_step_ref`` is the pure-jnp oracle every test
compares against.
"""

from . import lif_update, ref  # noqa: F401
