"""Pure-jnp oracle for the LIF exact-integration step.

Semantics are bit-for-bit those of the rust engine's
``IafPscExp::update_chunk`` (rust/src/models/iaf_psc_exp.rs):

1. non-refractory neurons get the propagator update, refractory ones
   hold their potential and count down;
2. synaptic currents decay and receive this step's ring-buffer input;
3. threshold crossers are reset and made refractory for ``ref_steps``.

State is ``float64`` (the paper stresses NEST's double-precision
numerics); the refractory counter rides along as float64 holding exact
small integers, which keeps the artifact single-dtype.
"""

import jax.numpy as jnp

# Parameter-vector layout shared by kernel, oracle and the rust runtime.
# (rust/src/runtime/mod.rs mirrors these indices.)
P_P11_EX = 0  # exp(-h/tau_syn_ex)
P_P11_IN = 1  # exp(-h/tau_syn_in)
P_P22 = 2  # exp(-h/tau_m)
P_P21_EX = 3  # current->voltage propagator (exc)
P_P21_IN = 4  # current->voltage propagator (inh)
P_P20_IE = 5  # p20 * I_e  (constant-input voltage increment)
P_THETA = 6  # threshold (rel. E_L)
P_V_RESET = 7  # reset value (rel. E_L)
P_REF_STEPS = 8  # refractory period in steps
N_PARAMS = 9


def lif_step_ref(v, i_ex, i_in, refr, in_ex, in_in, params):
    """One exact-integration step for a population batch.

    All arrays are rank-1 float64 of identical length; ``params`` is the
    length-``N_PARAMS`` vector above. Returns
    ``(v', i_ex', i_in', refr', spiked)`` with ``spiked`` as float64
    0.0/1.0 mask.
    """
    p11_ex = params[P_P11_EX]
    p11_in = params[P_P11_IN]
    p22 = params[P_P22]
    p21_ex = params[P_P21_EX]
    p21_in = params[P_P21_IN]
    p20_ie = params[P_P20_IE]
    theta = params[P_THETA]
    v_reset = params[P_V_RESET]
    ref_steps = params[P_REF_STEPS]

    not_ref = refr == 0.0
    v_prop = p22 * v + p21_ex * i_ex + p21_in * i_in + p20_ie
    v1 = jnp.where(not_ref, v_prop, v)
    refr1 = jnp.where(not_ref, refr, refr - 1.0)

    i_ex1 = p11_ex * i_ex + in_ex
    i_in1 = p11_in * i_in + in_in

    spiked = v1 >= theta
    v2 = jnp.where(spiked, v_reset, v1)
    refr2 = jnp.where(spiked, ref_steps, refr1)
    return v2, i_ex1, i_in1, refr2, spiked.astype(jnp.float64)


def microcircuit_params(h=0.1, tau_m=10.0, tau_syn_ex=0.5, tau_syn_in=0.5,
                        c_m=250.0, e_l=-65.0, v_th=-50.0, v_reset=-65.0,
                        t_ref=2.0, i_e=0.0):
    """The Potjans–Diesmann iaf_psc_exp propagators as a param vector."""
    import numpy as np

    def p21(tau_syn):
        a = tau_syn * tau_m / (c_m * (tau_m - tau_syn))
        return a * (np.exp(-h / tau_m) - np.exp(-h / tau_syn))

    p22 = np.exp(-h / tau_m)
    p20 = tau_m / c_m * (1.0 - p22)
    return np.array(
        [
            np.exp(-h / tau_syn_ex),
            np.exp(-h / tau_syn_in),
            p22,
            p21(tau_syn_ex),
            p21(tau_syn_in),
            p20 * i_e,
            v_th - e_l,
            v_reset - e_l,
            round(t_ref / h),
        ],
        dtype=np.float64,
    )
