"""Layer-1 Pallas kernel: the LIF exact-integration update.

HARDWARE ADAPTATION (DESIGN.md §9). NEST's update loop is a scalar CPU
loop over heterogeneous neuron objects with pointer-chasing into ring
buffers. On TPU we restructure it as a dense, tile-parallel state update:

* a population's state lives in contiguous ``[N]`` float64 vectors;
  the coordinator pads N to a multiple of the block size ``BLOCK``;
* ``BlockSpec`` tiles the neuron axis so each grid step streams one
  ``[BLOCK]`` tile HBM→VMEM, updates it entirely on the VPU (the update
  is element-wise FMA + compares — no MXU work), and streams it back;
* branchless ``where`` masks replace NEST's per-neuron branches
  (refractoriness, threshold) — no divergence penalty;
* the ring-buffer read becomes a dense per-step input vector prepared by
  the rust coordinator, so the kernel sees unit-stride input.

VMEM: 7 tiles × BLOCK × 8 B = 7·BLOCK·8 ≈ 57 KiB at BLOCK=1024 — far
below the ~16 MiB VMEM budget, leaving room for double-buffered
pipelining (estimated in EXPERIMENTS.md §Perf).

The kernel must be lowered with ``interpret=True``: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (  # noqa: F401  (re-exported for tests)
    N_PARAMS,
    P_P11_EX,
    P_P11_IN,
    P_P20_IE,
    P_P21_EX,
    P_P21_IN,
    P_P22,
    P_REF_STEPS,
    P_THETA,
    P_V_RESET,
)

# Neuron-axis tile. 1024 float64 lanes = 8 KiB per tile buffer.
BLOCK = 1024


def _lif_kernel(params_ref, v_ref, iex_ref, iin_ref, refr_ref, inex_ref,
                inin_ref, v_out, iex_out, iin_out, refr_out, spk_out):
    """One [BLOCK] tile of the update (runs per grid step)."""
    p11_ex = params_ref[P_P11_EX]
    p11_in = params_ref[P_P11_IN]
    p22 = params_ref[P_P22]
    p21_ex = params_ref[P_P21_EX]
    p21_in = params_ref[P_P21_IN]
    p20_ie = params_ref[P_P20_IE]
    theta = params_ref[P_THETA]
    v_reset = params_ref[P_V_RESET]
    ref_steps = params_ref[P_REF_STEPS]

    v = v_ref[...]
    i_ex = iex_ref[...]
    i_in = iin_ref[...]
    refr = refr_ref[...]

    not_ref = refr == 0.0
    v1 = jnp.where(not_ref, p22 * v + p21_ex * i_ex + p21_in * i_in + p20_ie, v)
    refr1 = jnp.where(not_ref, refr, refr - 1.0)

    iex_out[...] = p11_ex * i_ex + inex_ref[...]
    iin_out[...] = p11_in * i_in + inin_ref[...]

    spiked = v1 >= theta
    v_out[...] = jnp.where(spiked, v_reset, v1)
    refr_out[...] = jnp.where(spiked, ref_steps, refr1)
    spk_out[...] = spiked.astype(jnp.float64)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lif_step_pallas(v, i_ex, i_in, refr, in_ex, in_in, params, interpret=True):
    """Pallas-tiled LIF step over a padded population batch.

    Arrays are rank-1 float64 with ``len % BLOCK == 0`` (the caller
    pads); ``params`` is the length-``N_PARAMS`` vector of ``ref.py``.
    Returns ``(v', i_ex', i_in', refr', spiked)``.
    """
    n = v.shape[0]
    assert n % BLOCK == 0, f"population batch must be padded to {BLOCK}"
    grid = (n // BLOCK,)
    tile = pl.BlockSpec((BLOCK,), lambda i: (i,))
    # params are broadcast to every grid step (block index 0)
    pspec = pl.BlockSpec((N_PARAMS,), lambda i: (0,))
    shape = jax.ShapeDtypeStruct((n,), jnp.float64)
    return pl.pallas_call(
        _lif_kernel,
        grid=grid,
        in_specs=[pspec, tile, tile, tile, tile, tile, tile],
        out_specs=[tile, tile, tile, tile, tile],
        out_shape=[shape] * 5,
        interpret=interpret,
    )(params, v, i_ex, i_in, refr, in_ex, in_in)


def pad_to_block(x, fill=0.0):
    """Pad a rank-1 array up to the next BLOCK multiple."""
    import numpy as np

    n = x.shape[0]
    pad = (-n) % BLOCK
    if pad == 0:
        return np.asarray(x)
    return np.concatenate([np.asarray(x), np.full(pad, fill, dtype=x.dtype)])
