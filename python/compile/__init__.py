"""Build-time compile path: L2 jax model + L1 pallas kernels + AOT export.

Never imported at simulation time — rust loads the HLO artifacts directly.
float64 is enabled globally (the paper's double-precision requirement).
"""

import jax

jax.config.update("jax_enable_x64", True)
