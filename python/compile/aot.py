"""AOT export: lower the L2/L1 computation to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written (all float64, batch padded to BLOCK):

* ``lif_step_b{B}.hlo.txt``       — Pallas kernel path (interpret=True)
* ``lif_step_jnp_b{B}.hlo.txt``   — pure-jnp fallback path
* ``manifest.json``               — batch size, param layout, shapes

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent; the
Makefile skips the rebuild when inputs are unchanged).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from .kernels.lif_update import BLOCK, lif_step_pallas  # noqa: E402
from .kernels.ref import N_PARAMS, lif_step_ref  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(batch: int, use_pallas: bool) -> str:
    vec = jax.ShapeDtypeStruct((batch,), jnp.float64)
    pvec = jax.ShapeDtypeStruct((N_PARAMS,), jnp.float64)

    if use_pallas:
        def fn(v, i_ex, i_in, refr, in_ex, in_in, params):
            return lif_step_pallas(v, i_ex, i_in, refr, in_ex, in_in, params)
    else:
        def fn(v, i_ex, i_in, refr, in_ex, in_in, params):
            return lif_step_ref(v, i_ex, i_in, refr, in_ex, in_in, params)

    lowered = jax.jit(fn).lower(vec, vec, vec, vec, vec, vec, pvec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--batches",
        default=f"{BLOCK}",
        help="comma-separated batch sizes (multiples of BLOCK)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    batches = [int(b) for b in args.batches.split(",")]
    manifest = {
        "block": BLOCK,
        "n_params": N_PARAMS,
        "dtype": "f64",
        "artifacts": {},
        "inputs": ["v", "i_ex", "i_in", "refr", "in_ex", "in_in", "params"],
        "outputs": ["v", "i_ex", "i_in", "refr", "spiked"],
    }
    for b in batches:
        assert b % BLOCK == 0, f"batch {b} not a multiple of BLOCK={BLOCK}"
        for use_pallas, tag in [(True, ""), (False, "_jnp")]:
            name = f"lif_step{tag}_b{b}.hlo.txt"
            path = os.path.join(args.out, name)
            text = lower_step(b, use_pallas)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"][name] = {"batch": b, "pallas": use_pallas}
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
