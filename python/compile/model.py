"""Layer-2 JAX model: the microcircuit's population dynamics.

The paper's network-level coordination (spike routing, ring buffers,
MPI) is Layer-3 rust; what the compute layer owns is the *neuron state
update* of each population — the update phase that dominates the
simulation cycle. ``population_step`` is that update, built on the
Layer-1 Pallas kernel; ``population_step_jnp`` is the kernel-free
variant (pure jnp) lowered as a fallback artifact, and
``multi_step`` demonstrates L2 composition by scanning the kernel over
several steps with a fixed input (used by shape/AOT tests).
"""

import jax
import jax.numpy as jnp

from .kernels import lif_update, ref


def population_step(v, i_ex, i_in, refr, in_ex, in_in, params):
    """One update step of a (padded) population via the Pallas kernel."""
    return lif_update.lif_step_pallas(v, i_ex, i_in, refr, in_ex, in_in, params)


def population_step_jnp(v, i_ex, i_in, refr, in_ex, in_in, params):
    """Kernel-free reference path (same semantics, pure jnp)."""
    return ref.lif_step_ref(v, i_ex, i_in, refr, in_ex, in_in, params)


def multi_step(v, i_ex, i_in, refr, in_ex, in_in, params, n_steps=10):
    """Scan ``population_step_jnp`` over ``n_steps`` with constant input.

    Demonstrates that the L2 graph fuses into a single XLA while-loop
    (no per-step re-dispatch); spike masks are accumulated.
    """

    def body(carry, _):
        v, i_ex, i_in, refr, spikes = carry
        v, i_ex, i_in, refr, spiked = population_step_jnp(
            v, i_ex, i_in, refr, in_ex, in_in, params
        )
        return (v, i_ex, i_in, refr, spikes + spiked), None

    init = (v, i_ex, i_in, refr, jnp.zeros_like(v))
    (v, i_ex, i_in, refr, spikes), _ = jax.lax.scan(body, init, None, length=n_steps)
    return v, i_ex, i_in, refr, spikes
