//! Serving-mode benchmark: N concurrent microcircuit sessions hosted
//! by `runtime::serving::SessionServer`, one consumer thread draining
//! each spike stream, under the lossless `block` back-pressure policy.
//!
//! The load generator reuses the scenario sweep's cell axes
//! (`coordinator::scenario::build_cell_sim`), so the per-session
//! workload is the same microcircuit the trajectory benches measure.
//! Reported per session: intervals served, spikes streamed, queue
//! drops (must be zero under `block`) and the p50/p99 interval service
//! latency; aggregated: sessions/node and the worst-session p99 —
//! persisted as a versioned record in `BENCH_serving.json` at the
//! repository root.
//!
//! Run: `cargo bench --bench bench_serving` (append `-- --quick` for
//! the CI smoke sizing: 2 sessions × a small net). Exits non-zero if
//! any batch is dropped or any stream loses a batch — the lossless
//! claim of the blocking policy, enforced on every CI run.

use nsim::coordinator::scenario::{
    self, BackendSel, Kernel, ScenarioCell, Schedule, TransportSel,
};
use nsim::hw::Fingerprint;
use nsim::runtime::serving::{BackpressurePolicy, SessionConfig, SessionServer};
use nsim::util::json::{write_file, Json};
use nsim::util::table::{Align, Table};

/// Schema identifier of `BENCH_serving.json`.
const SCHEMA: &str = "nsim.bench_serving";
/// Bump when the record layout changes incompatibly.
const SCHEMA_VERSION: u64 = 1;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n_sessions: usize = if quick { 2 } else { 4 };
    let t_model_ms = if quick { 100.0 } else { 250.0 };
    let cell = ScenarioCell {
        d_min_ms: 0.5,
        scale: if quick { 0.02 } else { 0.05 },
        n_ranks: 1,
        n_threads: 2,
        transport: TransportSel::Loopback,
        schedule: Schedule::Adaptive,
        backend: BackendSel::Native,
        kernel: Kernel::Vector,
    };
    let seed = 55_374u64;
    println!(
        "# serving benchmark — {n_sessions} sessions × (scale {}, d_min {} ms, {} threads), \
         {t_model_ms} ms each, policy block\n",
        cell.scale, cell.d_min_ms, cell.n_threads
    );

    let mut srv = SessionServer::new();
    let mut consumers = Vec::new();
    for i in 0..n_sessions {
        let sim = scenario::build_cell_sim(&cell, seed + i as u64).expect("build session");
        let (id, stream) = srv.open(
            sim,
            t_model_ms,
            SessionConfig {
                capacity: 64,
                policy: BackpressurePolicy::Block,
                ..Default::default()
            },
        );
        consumers.push((
            id,
            std::thread::spawn(move || {
                let mut batches = 0u64;
                while stream.recv().is_some() {
                    batches += 1;
                }
                batches
            }),
        ));
    }
    let t0 = std::time::Instant::now();
    let ticks = srv.run_until_idle();
    let wall_s = t0.elapsed().as_secs_f64();

    let mut t = Table::new([
        "session",
        "intervals",
        "spikes",
        "recv batches",
        "dropped",
        "p50 [ms]",
        "p99 [ms]",
    ])
    .align(0, Align::Left);
    let mut sessions_json = Vec::new();
    let mut failures = Vec::new();
    let mut p99_worst: f64 = 0.0;
    let mut p50_worst: f64 = 0.0;
    for (id, handle) in consumers {
        let batches = handle.join().expect("consumer thread");
        let st = srv.stats(id).expect("session stats");
        t.add_row([
            id.to_string(),
            st.intervals_served.to_string(),
            st.spikes_streamed.to_string(),
            batches.to_string(),
            st.batches_dropped.to_string(),
            format!("{:.3}", st.p50_interval_ms),
            format!("{:.3}", st.p99_interval_ms),
        ]);
        if !st.done {
            failures.push(format!("{id}: did not reach its horizon"));
        }
        if st.batches_dropped > 0 {
            failures.push(format!(
                "{id}: {} batch(es) dropped under the blocking policy",
                st.batches_dropped
            ));
        }
        if batches != st.intervals_served {
            failures.push(format!(
                "{id}: consumer received {batches} of {} batches",
                st.intervals_served
            ));
        }
        p99_worst = p99_worst.max(st.p99_interval_ms);
        p50_worst = p50_worst.max(st.p50_interval_ms);
        let mut o = Json::obj();
        o.set("id", Json::from(st.id.raw()))
            .set("intervals_served", Json::from(st.intervals_served))
            .set("steps_done", Json::from(st.steps_done))
            .set("spikes_streamed", Json::from(st.spikes_streamed))
            .set("batches_received", Json::from(batches))
            .set("batches_dropped", Json::from(st.batches_dropped))
            .set("p50_interval_ms", Json::from(st.p50_interval_ms))
            .set("p99_interval_ms", Json::from(st.p99_interval_ms));
        sessions_json.push(o);
    }
    t.print();
    println!(
        "\nserved {ticks} intervals in {wall_s:.2} s ({:.1} intervals/s); \
         worst-session p99 {p99_worst:.3} ms",
        ticks as f64 / wall_s.max(1e-9)
    );

    let mut axes = Json::obj();
    axes.set("d_min_ms", Json::from(cell.d_min_ms))
        .set("scale", Json::from(cell.scale))
        .set("n_threads", Json::from(cell.n_threads))
        .set("policy", Json::from("block"))
        .set("capacity", Json::from(64u64))
        .set("t_model_ms", Json::from(t_model_ms))
        .set("seed", Json::from(seed));
    let mut agg = Json::obj();
    agg.set("sessions_per_node", Json::from(n_sessions))
        .set("intervals_served", Json::from(ticks))
        .set("wall_s", Json::from(wall_s))
        .set(
            "intervals_per_s",
            Json::from(ticks as f64 / wall_s.max(1e-9)),
        )
        .set("p50_worst_ms", Json::from(p50_worst))
        .set("p99_worst_ms", Json::from(p99_worst));
    let mut o = Json::obj();
    o.set("schema", Json::from(SCHEMA))
        .set("schema_version", Json::from(SCHEMA_VERSION))
        .set("quick", Json::from(quick))
        .set("git_rev", Json::from(scenario::git_rev()))
        .set("machine", Fingerprint::capture().to_json())
        .set("workload", axes)
        .set("aggregate", agg)
        .set("sessions", Json::Arr(sessions_json));
    write_file("BENCH_serving.json", &o).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("no drops, every stream complete: blocking policy is lossless");
}
