//! Bench E3 — regenerates **Fig 1c**: power traces of the three node
//! configurations over 100 s of model time (top panels) and cumulative
//! energy (bottom panel), via the calibrated power model + PDU
//! measurement simulator.
//!
//! Run: `cargo bench --bench bench_fig1c`.

use nsim::coordinator::energy::energy_experiment;
use nsim::hw::calib::anchors;
use nsim::hw::{Calib, PowerCalib, Workload};
use nsim::util::json::write_file;
use nsim::util::table::Table;

fn main() {
    println!("# Fig 1c — power and energy, 100 s of model time\n");
    let res = energy_experiment(
        &Workload::microcircuit_full(),
        &Calib::default(),
        &PowerCalib::default(),
        100.0,
        1,
    );

    let mut t = Table::new([
        "config",
        "RTF",
        "T_wall [s]",
        "P-base [kW]",
        "paper [kW]",
        "E_sim [kJ]",
        "E/event [µJ]",
    ]);
    let paper = [
        anchors::POWER_SEQ_64_KW,
        anchors::POWER_DIST_64_KW,
        anchors::POWER_SEQ_128_KW,
    ];
    for (r, p) in res.rows.iter().zip(paper) {
        t.add_row([
            r.label.clone(),
            format!("{:.3}", r.pred.rtf),
            format!("{:.1}", r.t_wall_s),
            format!("{:.3}", (r.power_w - 200.0) / 1e3),
            format!("{p:.2}"),
            format!("{:.1}", r.energy_j / 1e3),
            format!("{:.3}", r.e_per_event_uj),
        ]);
    }
    t.print();

    // cumulative energy series (the bottom panel) at 10 s resolution
    println!("\ncumulative energy [kJ] (PDU-integrated):");
    for r in &res.rows {
        let cum = r.trace.cumulative_energy();
        let pick: Vec<String> = cum
            .iter()
            .filter(|(t, _)| (*t as u64) % 10 == 0)
            .map(|(t, e)| format!("{t:.0}s:{:.1}", e / 1e3))
            .collect();
        println!("  {:<8} {}", r.label, pick.join("  "));
    }

    // paper-claim assertions
    let seq64 = res.row("seq-64").unwrap();
    let dist64 = res.row("dist-64").unwrap();
    let seq128 = res.row("seq-128").unwrap();
    assert!(dist64.power_w > seq64.power_w, "distant draws more power");
    assert!(
        seq128.energy_j < seq64.energy_j && seq128.energy_j < dist64.energy_j,
        "full node = least energy (paper's conclusion)"
    );
    assert!(
        seq128.t_wall_s < seq64.t_wall_s && seq128.t_wall_s < dist64.t_wall_s,
        "full node = fastest"
    );

    write_file("bench_results/fig1c.json", &res.to_json()).expect("write json");
    println!("\nOK — wrote bench_results/fig1c.json");
}
