//! Engine micro-benchmarks (§Perf baseline) + model ablations:
//!
//! * neuron-update throughput (exact integration incl. Poisson drive),
//! * update-kernel ablation: scalar loop vs the lane-blocked vectorized
//!   kernel, at a subthreshold state (pure integration) and under a
//!   high-rate drive (branchless spike compress exercised) — recorded
//!   as `update_kernel_ablation` in `BENCH_micro.json`,
//! * spike-delivery throughput ablation: dense CSR (sorted + unsorted
//!   draw order) vs the compressed, delay-sliced delivery plan,
//! * ring-buffer row read/clear bandwidth,
//! * Poisson sampling rate,
//! * ablation: `iaf_psc_exp` vs `iaf_psc_delta` update cost (what the
//!   synaptic-current dynamics cost, DESIGN.md ablation),
//! * min-delay interval sweep (comm rounds vs phase split),
//! * threaded-schedule ablation: serial-merge/static partitions vs the
//!   pipelined cycle (gid-sliced parallel merge + work-stealing
//!   deliver), per-thread phase spans incl. `Phase::Idle`,
//! * clustered-activity slicing ablation: equal-width vs
//!   mass-proportional (adaptive) merge slices on a hot/cold gid-space
//!   split — per-run merge max−min packet span, slice imbalance and
//!   deliver spread,
//! * transport ablation: the same 2-rank run over the localhost TCP
//!   mesh vs the shared-memory rings — per-round wire (pack + unpack),
//!   blocking wait and post-overlap residual wait from
//!   `TransportStats`, recorded as `transport_ablation`,
//! * fault-recovery ablation: the same 2-rank loopback run clean vs
//!   under a seeded fault plan (drops, duplicates, one corrupted
//!   frame) — retry/recovery counters, wall overhead and a
//!   bit-identity check, recorded as `fault_recovery_ablation`,
//! * end-to-end engine step at scale 0.1.
//!
//! Run: `cargo bench --bench bench_micro` (append `-- --quick` for the
//! CI-sized variant). Results feed EXPERIMENTS.md §Perf (before/after
//! table) and are persisted as a machine-readable trajectory record in
//! `BENCH_micro.json` at the repository root (RTF, phase split,
//! bytes/synapse, deliver-scan skip rate, ablation throughputs,
//! per-thread schedule spans) so future PRs regress against a baseline.

use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::engine::RingBuffer;
use nsim::models::{IafParams, IafPscDelta, IafPscExp, NeuronState, PoissonSource, RESOLUTION_MS};
use nsim::util::rng::Pcg64;
use nsim::util::table::Table;
use nsim::util::timer::bench_runs;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    if quick {
        println!("# engine micro-benchmarks — QUICK (CI) sizing\n");
    } else {
        println!("# engine micro-benchmarks (1 core, this container)\n");
    }
    let mut t = Table::new(["benchmark", "throughput", "per-op"]);
    let iters = if quick { 3 } else { 10 };

    // --- neuron update: scalar vs vectorized kernel -------------------------
    // Same mixed initial state for every kernel×drive cell. Subthreshold
    // drive measures pure exact integration; the high-rate drive keeps a
    // visible fraction of lanes spiking/refractory every step, so the
    // branchless select + mask-compress path is exercised too.
    let n = if quick { 20_000 } else { 100_000 };
    let model = IafPscExp::new(&IafParams::default(), RESOLUTION_MS);
    let in_ex = vec![5.0; n];
    let in_in = vec![-2.0; n];
    let mut spikes = Vec::new();
    let mixed_state = || {
        let mut st = NeuronState::with_len(n);
        let mut rng = Pcg64::seed_from_u64(1);
        for i in 0..n {
            st.v_m[i] = rng.uniform() * 20.0 - 5.0;
        }
        st
    };
    let mut kernel_ns = |vectorized: bool, drive: f64| -> f64 {
        let mut st = mixed_state();
        let inx = vec![drive; n];
        let inn = vec![-2.0; n];
        let s = bench_runs(3, iters, || {
            spikes.clear();
            if vectorized {
                model.update_chunk_vectorized(&mut st, 0, n, &inx, &inn, &mut spikes);
            } else {
                model.update_chunk(&mut st, 0, n, &inx, &inn, &mut spikes);
            }
        });
        s.median() / n as f64 * 1e9
    };
    let scalar_sub_ns = kernel_ns(false, 5.0);
    let vector_sub_ns = kernel_ns(true, 5.0);
    let scalar_hot_ns = kernel_ns(false, 150.0);
    let vector_hot_ns = kernel_ns(true, 150.0);
    for (label, ns) in [
        ("neuron update (iaf_psc_exp, scalar)", scalar_sub_ns),
        ("neuron update (iaf_psc_exp, vector)", vector_sub_ns),
        ("neuron update (high rate, scalar)", scalar_hot_ns),
        ("neuron update (high rate, vector)", vector_hot_ns),
    ] {
        t.add_row([
            label.to_string(),
            format!("{:.1} M/s", 1e3 / ns),
            format!("{ns:.2} ns"),
        ]);
    }
    println!(
        "update-kernel speedup (scalar/vector): subthreshold {:.2}x, high rate {:.2}x\n",
        scalar_sub_ns / vector_sub_ns.max(1e-12),
        scalar_hot_ns / vector_hot_ns.max(1e-12),
    );

    // --- ablation: delta model ---------------------------------------------
    let delta = IafPscDelta::new(&IafParams::default(), RESOLUTION_MS);
    let mut st2 = NeuronState::with_len(n);
    let s2 = bench_runs(3, iters, || {
        spikes.clear();
        delta.update_chunk(&mut st2, 0, n, &in_ex, &in_in, &mut spikes);
    });
    let per_op2 = s2.median() / n as f64;
    t.add_row([
        "neuron update (iaf_psc_delta)".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op2),
        format!("{:.2} ns", per_op2 * 1e9),
    ]);

    // --- Poisson sampling ---------------------------------------------------
    let src = PoissonSource::new(12_800.0, 87.8, RESOLUTION_MS);
    let mut acc = vec![0.0; n];
    let mut prng = Pcg64::seed_from_u64(2);
    let s3 = bench_runs(3, iters, || {
        src.sample_into(&mut prng, &mut acc);
    });
    let per_op3 = s3.median() / n as f64;
    t.add_row([
        "poisson drive sample".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op3),
        format!("{:.2} ns", per_op3 * 1e9),
    ]);

    // --- ring buffer ---------------------------------------------------------
    let mut rb = RingBuffer::new(n, 80);
    let mut row = vec![0.0; n];
    let s4 = bench_runs(3, 2 * iters, || {
        rb.take_row_into(3, &mut row);
    });
    t.add_row([
        "ring-buffer row read+clear".to_string(),
        format!("{:.1} GB/s", n as f64 * 8.0 / s4.median() / 1e9),
        format!("{:.2} ns/neuron", s4.median() / n as f64 * 1e9),
    ]);

    // --- delivery ablation: dense CSR vs compressed plan ----------------------
    // Realistic target rows: one full-scale-density source population.
    // Three structures over the *same* connections: the dense CSR in
    // draw order (unsorted ablation), the dense CSR (delay, target)-
    // sorted (the old engine hot path), and the compressed delay-sliced
    // plan (the new hot path: run-sliced scatter, 8 B payload).
    let mut csr_ns_per_event = 0.0;
    let mut csr_unsorted_ns_per_event = 0.0;
    let mut plan_ns_per_event = 0.0;
    {
        use nsim::connection::{DeliveryPlanBuilder, TargetTableBuilder};
        let n_src = if quick { 2_000u32 } else { 10_000u32 };
        let out_deg = 1000usize;
        let gen_conns = |b: &mut dyn FnMut(u32, u32, f64, u16)| {
            let mut crng = Pcg64::seed_from_u64(3);
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b(
                        src,
                        crng.below(n as u64) as u32,
                        if crng.uniform() < 0.8 { 87.8 } else { -351.2 },
                        1 + crng.below(60) as u16,
                    );
                }
            }
        };
        let build_csr = |sorted: bool| {
            let mut b = TargetTableBuilder::new(n_src as usize);
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b.count(src);
                }
            }
            b.start_fill();
            gen_conns(&mut |src, tgt, w, d| b.push(src, tgt, w, d));
            if sorted {
                b.finish()
            } else {
                b.finish_unsorted()
            }
        };
        let plan = {
            let mut b = DeliveryPlanBuilder::new(n_src as usize);
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b.count(src);
                }
            }
            b.start_fill();
            gen_conns(&mut |src, tgt, w, d| b.push(src, tgt, w, d));
            b.finish()
        };
        let mut crng = Pcg64::seed_from_u64(4);
        let spikers: Vec<u32> = (0..200).map(|_| crng.below(n_src as u64) as u32).collect();
        let events_per_iter = spikers.len() as u64 * out_deg as u64;

        for (sorted, label) in [
            (true, "deliver: dense CSR (sorted rows)"),
            (false, "deliver: dense CSR (unsorted, ablation)"),
        ] {
            let table = build_csr(sorted);
            let mut ring_ex = RingBuffer::new(n, 80);
            let mut ring_in = RingBuffer::new(n, 80);
            let s5 = bench_runs(3, 2 * iters, || {
                for &gid in &spikers {
                    let (tgts, ws, ds) = table.outgoing(gid);
                    for i in 0..tgts.len() {
                        let w = ws[i];
                        if w >= 0.0 {
                            ring_ex.add(7 + ds[i] as u64, tgts[i], w);
                        } else {
                            ring_in.add(7 + ds[i] as u64, tgts[i], w);
                        }
                    }
                }
            });
            let per_ev = s5.median() / events_per_iter as f64;
            if sorted {
                csr_ns_per_event = per_ev * 1e9;
            } else {
                csr_unsorted_ns_per_event = per_ev * 1e9;
            }
            t.add_row([
                label.to_string(),
                format!("{:.1} M events/s", 1e-6 / per_ev),
                format!("{:.2} ns", per_ev * 1e9),
            ]);
        }
        {
            // the engine's run-sliced scatter: one ring row per delay run
            let mut ring_ex = RingBuffer::new(n, 80);
            let mut ring_in = RingBuffer::new(n, 80);
            let s5 = bench_runs(3, 2 * iters, || {
                for &gid in &spikers {
                    let row = plan.row_of(gid).expect("dense bench: all present");
                    let (tgts, ws) = plan.row_synapses(row);
                    let (run_d, run_c) = plan.row_runs(row);
                    let mut base = 0usize;
                    for (&d, &c) in run_d.iter().zip(run_c.iter()) {
                        let end = base + c as usize;
                        let row_ex = ring_ex.row_mut(7 + d as u64);
                        let row_in = ring_in.row_mut(7 + d as u64);
                        for i in base..end {
                            let w = ws[i] as f64;
                            if w >= 0.0 {
                                row_ex[tgts[i] as usize] += w;
                            } else {
                                row_in[tgts[i] as usize] += w;
                            }
                        }
                        base = end;
                    }
                }
            });
            let per_ev = s5.median() / events_per_iter as f64;
            plan_ns_per_event = per_ev * 1e9;
            t.add_row([
                "deliver: compressed plan (runs)".to_string(),
                format!("{:.1} M events/s", 1e-6 / per_ev),
                format!("{:.2} ns", per_ev * 1e9),
            ]);
        }
    }

    // --- min-delay interval sweep ----------------------------------------------
    // Same connectivity and drive, delays scaled so d_min = 1, 5, 15 steps:
    // the interval cycle runs steps/d_min communication rounds, so the
    // communicate phase (and its per-round fixed cost) shrinks accordingly
    // while update work is unchanged. Feeds the BENCH_micro.json trajectory.
    let sweep_skip_rate;
    let sweep_t_ms = if quick { 100.0 } else { 500.0 };
    {
        use nsim::engine::{Decomposition, SimConfig, Simulator};
        use nsim::models::ModelKind;
        use nsim::network::rules::{weight_dist, ConnRule};
        use nsim::network::{build, Dist, NetworkSpec};
        use nsim::util::table::fmt_count;
        use nsim::util::timer::Phase;

        println!(
            "\n# min-delay interval sweep ({sweep_t_ms} ms model time, 4 VPs on 2 ranks)\n"
        );
        let mut ti = Table::new([
            "d_min [steps]",
            "comm rounds",
            "bytes sent",
            "deliver skip",
            "update [ms]",
            "communicate [ms]",
            "deliver [ms]",
        ]);
        // one sweep cell: (rounds, bytes sent, skip rate, update /
        // communicate / deliver ms)
        let run_cell = |d_min: u16| -> (u64, u64, f64, f64, f64, f64) {
            let d_ms = d_min as f64 * RESOLUTION_MS;
            let v0 = Dist::ClippedNormal {
                mean: -58.0,
                std: 5.0,
                lo: f64::NEG_INFINITY,
                hi: -50.000001,
            };
            let mut s = NetworkSpec::new(RESOLUTION_MS, 42);
            let e = s.add_population(
                "E",
                2000,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            let i = s.add_population(
                "I",
                500,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            // delays: d_min on the inhibitory loop, 3·d_min elsewhere
            s.connect(
                e,
                e,
                ConnRule::FixedTotalNumber { n: 20_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(d_ms * 3.0),
            );
            s.connect(
                e,
                i,
                ConnRule::FixedTotalNumber { n: 5_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(d_ms * 3.0),
            );
            s.connect(
                i,
                e,
                ConnRule::FixedTotalNumber { n: 5_000 },
                weight_dist(-351.2, 0.1),
                Dist::Const(d_ms),
            );
            s.connect(
                i,
                i,
                ConnRule::FixedTotalNumber { n: 1_250 },
                weight_dist(-351.2, 0.1),
                Dist::Const(d_ms),
            );
            let net = build(&s, Decomposition::new(2, 2));
            assert_eq!(net.min_delay_steps, d_min);
            let mut sim = Simulator::new(
                net,
                SimConfig {
                    record_spikes: false,
                    os_threads: 1,
                    pipelined: true,
                    adaptive: true,
                    vectorize: true,
                },
            );
            let res = sim.simulate(sweep_t_ms);
            (
                // VP 0 of rank 0: rounds this rank participated in
                res.per_vp_counters[0].comm_rounds,
                res.counters.comm_bytes_sent,
                // sparse out-degrees (~12 over 4 VPs) ⇒ the presence
                // merge-join skips a visible fraction of the packet scans
                res.counters.deliver_skip_rate(),
                res.timers.get(Phase::Update).as_secs_f64() * 1e3,
                res.timers.get(Phase::Communicate).as_secs_f64() * 1e3,
                res.timers.get(Phase::Deliver).as_secs_f64() * 1e3,
            )
        };
        // the d_min = 1 baseline cell is run ONCE, up front: the loop
        // reuses its result for both the trajectory skip rate and its
        // table row instead of re-running the cell (--quick CI time)
        let baseline = run_cell(1);
        sweep_skip_rate = baseline.2;
        for d_min in [1u16, 5, 15] {
            let cell = if d_min == 1 { baseline } else { run_cell(d_min) };
            let (rounds, bytes, skip, update_ms, comm_ms, deliver_ms) = cell;
            ti.add_row([
                format!("{d_min}"),
                format!("{rounds}"),
                fmt_count(bytes),
                format!("{:.1} %", skip * 100.0),
                format!("{update_ms:.2}"),
                format!("{comm_ms:.3}"),
                format!("{deliver_ms:.2}"),
            ]);
        }
        ti.print();
        println!("(steps / d_min rounds: communicate's latency share falls)");
    }

    // --- threaded-schedule ablation --------------------------------------------
    // Serial-merge static partitions vs the pipelined cycle (gid-sliced
    // parallel merge + work-stealing deliver), 4 OS threads over 32 VPs.
    // A small hub population H occupies VPs 0..8 — exactly thread 0's
    // static partition — and takes a dense E→H projection, so deliver
    // mass concentrates on one thread under the static schedule; the
    // work queue spreads those eight heavy VP tasks over all threads.
    // Per-thread own-work spans (incl. Phase::Idle) feed the trajectory:
    // (a) the pipelined schedule must show merge work on EVERY thread,
    // (b) the max−min spread of the deliver spans must shrink.
    struct SchedSpans {
        comm_ms: Vec<f64>,
        deliver_ms: Vec<f64>,
        idle_ms: Vec<f64>,
        update_ms: Vec<f64>,
        stolen: u64,
    }
    let ablation_t_ms = if quick { 100.0 } else { 300.0 };
    let (sched_static, sched_pipe) = {
        use nsim::engine::{Decomposition, SimConfig, Simulator};
        use nsim::models::ModelKind;
        use nsim::network::rules::{weight_dist, ConnRule};
        use nsim::network::{build, Dist, NetworkSpec};
        use nsim::util::timer::Phase;

        let make_net = || {
            let v0 = Dist::ClippedNormal {
                mean: -58.0,
                std: 5.0,
                lo: f64::NEG_INFINITY,
                hi: -50.000001,
            };
            let mut s = NetworkSpec::new(RESOLUTION_MS, 77);
            let e = s.add_population(
                "E",
                3200,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            // 3200 % 32 == 0 ⇒ H's gids land on VPs 0..8
            let h = s.add_population(
                "H",
                8,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                Dist::Const(-65.0),
                0.0,
                0.0,
            );
            s.connect(
                e,
                e,
                ConnRule::FixedTotalNumber { n: 32_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(0.5),
            );
            // the hub: ~100 synapses onto VPs 0..8 per spiking source
            s.connect(
                e,
                h,
                ConnRule::FixedTotalNumber { n: 320_000 },
                weight_dist(0.878, 0.1),
                Dist::Const(0.5),
            );
            build(&s, Decomposition::new(1, 32))
        };
        let run = |pipelined: bool| -> SchedSpans {
            let mut sim = Simulator::new(
                make_net(),
                SimConfig {
                    record_spikes: false,
                    os_threads: 4,
                    pipelined,
                    // the hub ablation isolates the PR 3 queue: plain LPT
                    adaptive: false,
                    vectorize: true,
                },
            );
            let r = sim.simulate(ablation_t_ms);
            let ms = |ph: Phase| -> Vec<f64> {
                r.per_thread_timers
                    .iter()
                    .map(|pt| pt.get(ph).as_secs_f64() * 1e3)
                    .collect()
            };
            SchedSpans {
                comm_ms: ms(Phase::Communicate),
                deliver_ms: ms(Phase::Deliver),
                idle_ms: ms(Phase::Idle),
                update_ms: ms(Phase::Update),
                stolen: r.counters.deliver_tasks_stolen,
            }
        };
        (run(false), run(true))
    };
    let spread = |v: &[f64]| -> f64 {
        let max = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
        max - min
    };
    println!(
        "\n# threaded-schedule ablation ({ablation_t_ms} ms model time, 32 VPs, 4 OS threads)\n"
    );
    let mut ta = Table::new([
        "schedule",
        "thread",
        "update [ms]",
        "communicate [ms]",
        "deliver [ms]",
        "idle [ms]",
    ]);
    for (name, sp) in [
        ("serial merge + static", &sched_static),
        ("parallel merge + steal", &sched_pipe),
    ] {
        for th in 0..sp.comm_ms.len() {
            ta.add_row([
                if th == 0 { name.to_string() } else { String::new() },
                format!("{th}"),
                format!("{:.2}", sp.update_ms[th]),
                format!("{:.3}", sp.comm_ms[th]),
                format!("{:.2}", sp.deliver_ms[th]),
                format!("{:.2}", sp.idle_ms[th]),
            ]);
        }
    }
    ta.print();
    let all_threads_merge = sched_pipe.comm_ms.iter().all(|&ms| ms > 0.0);
    let static_spread = spread(&sched_static.deliver_ms);
    let pipe_spread = spread(&sched_pipe.deliver_ms);
    println!(
        "deliver-span spread (max−min): static {static_spread:.2} ms → pipelined \
         {pipe_spread:.2} ms | merge on all threads: {all_threads_merge} | \
         tasks stolen: {}",
        sched_pipe.stolen
    );
    if !all_threads_merge || pipe_spread >= static_spread {
        println!("WARNING: pipelined schedule did not dominate on this box/run");
    }

    // --- clustered-activity slicing ablation ------------------------------------
    // Population A (the first half of the gid space) fires under strong
    // drive; B is silent, so the published packet mass is gid-clustered.
    // Equal-width merge slices leave half the slice set empty every
    // interval (merge_slice_min_packets == 0) while one slice merges
    // ~half of everything; the adaptive schedule re-sizes the slices
    // from the previous interval's per-slice mass and must show a
    // smaller max−min span. Slice masses are deterministic counters, so
    // the span comparison is noise-free; the deliver spread is the
    // wall-clock side of the same story.
    struct SliceAblation {
        merge_max: u64,
        merge_min: u64,
        imbalance: f64,
        deliver_spread_ms: f64,
        stolen: u64,
        local: u64,
    }
    let clustered_t_ms = if quick { 100.0 } else { 300.0 };
    let (slice_eq, slice_ad) = {
        use nsim::engine::{Decomposition, SimConfig, Simulator};
        use nsim::models::ModelKind;
        use nsim::network::rules::{weight_dist, ConnRule};
        use nsim::network::{build, Dist, NetworkSpec};
        use nsim::util::timer::Phase;

        let make_net = || {
            let v0 = Dist::ClippedNormal {
                mean: -56.0,
                std: 4.0,
                lo: f64::NEG_INFINITY,
                hi: -50.000001,
            };
            let mut s = NetworkSpec::new(RESOLUTION_MS, 91);
            let a = s.add_population(
                "A",
                2000,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                16_000.0,
                87.8,
            );
            let b = s.add_population(
                "B",
                2000,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                Dist::Const(-65.0),
                0.0,
                0.0,
            );
            s.connect(
                a,
                a,
                ConnRule::FixedTotalNumber { n: 20_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(0.5), // 5-step interval: dense per-interval mass
            );
            // sub-threshold drive onto B: deliver work everywhere, but
            // the *spike* mass stays clustered in A's gid range
            s.connect(
                a,
                b,
                ConnRule::FixedTotalNumber { n: 10_000 },
                weight_dist(8.78, 0.1),
                Dist::Const(0.5),
            );
            build(&s, Decomposition::new(1, 8))
        };
        let run = |adaptive: bool| -> SliceAblation {
            let mut sim = Simulator::new(
                make_net(),
                SimConfig {
                    record_spikes: false,
                    os_threads: 4,
                    pipelined: true,
                    adaptive,
                    vectorize: true,
                },
            );
            let r = sim.simulate(clustered_t_ms);
            let deliver_ms: Vec<f64> = r
                .per_thread_timers
                .iter()
                .map(|pt| pt.get(Phase::Deliver).as_secs_f64() * 1e3)
                .collect();
            SliceAblation {
                merge_max: r.counters.merge_slice_max_packets,
                merge_min: r.counters.merge_slice_min_packets,
                imbalance: r.merge_slice_imbalance(),
                deliver_spread_ms: spread(&deliver_ms),
                stolen: r.counters.deliver_tasks_stolen,
                local: r.counters.deliver_tasks_local,
            }
        };
        (run(false), run(true))
    };
    println!(
        "\n# clustered-activity slicing ablation ({clustered_t_ms} ms model time, \
         hot/cold gid halves, 8 VPs, 4 OS threads)\n"
    );
    let mut tc = Table::new([
        "slicing",
        "merge max [pkts]",
        "merge min [pkts]",
        "max-min span",
        "imbalance",
        "deliver spread [ms]",
        "local/stolen",
    ]);
    for (name, s) in [
        ("equal width", &slice_eq),
        ("mass-proportional", &slice_ad),
    ] {
        tc.add_row([
            name.to_string(),
            format!("{}", s.merge_max),
            format!("{}", s.merge_min),
            format!("{}", s.merge_max - s.merge_min),
            format!("{:.3}", s.imbalance),
            format!("{:.2}", s.deliver_spread_ms),
            format!("{}/{}", s.local, s.stolen),
        ]);
    }
    tc.print();
    let span_eq = slice_eq.merge_max - slice_eq.merge_min;
    let span_ad = slice_ad.merge_max - slice_ad.merge_min;
    if span_ad >= span_eq {
        println!("WARNING: adaptive slicing did not narrow the merge span");
    }
    if slice_ad.deliver_spread_ms > slice_eq.deliver_spread_ms {
        println!("note: adaptive deliver spread above equal-width on this box/run");
    }

    // --- transport ablation: tcp sockets vs shared-memory rings -----------------
    // The same 2-rank network, run as two rank-local simulators in one
    // process — once over the localhost TCP mesh, once over the mmap'd
    // SPSC rings. `TransportStats` splits the per-round cost into wire
    // work (pack + unpack), blocking completion wait and post-overlap
    // residual wait; the rings must cut wire + wait per round, and the
    // non-blocking round overlap keeps the residual small.
    struct TransportCell {
        rounds: u64,
        wire_us_per_round: f64,
        wait_us_per_round: f64,
        residual_us_per_round: f64,
        bytes_per_round: f64,
        posts: u64,
        polls: u64,
    }
    let transport_t_ms = if quick { 100.0 } else { 300.0 };
    let shm_supported = cfg!(all(target_os = "linux", target_arch = "x86_64"));
    let (trans_tcp, trans_shm) = {
        use nsim::comm::transport::TcpTransport;
        use nsim::comm::{RendezvousGuard, ShmTransport, Transport, TransportStats};
        use nsim::engine::{Decomposition, SimConfig, Simulator};
        use nsim::models::ModelKind;
        use nsim::network::rules::{weight_dist, ConnRule};
        use nsim::network::{build, Dist, NetworkSpec};

        let make_spec = || {
            let v0 = Dist::ClippedNormal {
                mean: -58.0,
                std: 5.0,
                lo: f64::NEG_INFINITY,
                hi: -50.000001,
            };
            let mut s = NetworkSpec::new(RESOLUTION_MS, 101);
            let e = s.add_population(
                "E",
                2000,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            let i = s.add_population(
                "I",
                500,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            // d_min = 5 steps: interval-batched rounds, real payloads
            s.connect(
                e,
                e,
                ConnRule::FixedTotalNumber { n: 20_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(1.5),
            );
            s.connect(
                i,
                e,
                ConnRule::FixedTotalNumber { n: 5_000 },
                weight_dist(-351.2, 0.1),
                Dist::Const(0.5),
            );
            s
        };
        let run = |shm: bool| -> TransportCell {
            let guard = RendezvousGuard::create("bench-transport").expect("rendezvous dir");
            let dir = guard.path().to_path_buf();
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let spec = make_spec();
                    let dir = dir.clone();
                    std::thread::spawn(move || {
                        let tr: Box<dyn Transport> = if shm {
                            Box::new(ShmTransport::connect(rank, 2, &dir).expect("shm connect"))
                        } else {
                            Box::new(TcpTransport::connect(rank, 2, &dir).expect("tcp connect"))
                        };
                        let mut sim = Simulator::new(
                            build(&spec, Decomposition::new(2, 2)),
                            SimConfig {
                                record_spikes: false,
                                os_threads: 2,
                                pipelined: true,
                                adaptive: true,
                                vectorize: true,
                            },
                        );
                        sim.set_transport(tr).expect("attach transport");
                        let _ = sim.simulate(transport_t_ms);
                        sim.transport_stats().expect("transport stats")
                    })
                })
                .collect();
            let stats: Vec<TransportStats> = handles
                .into_iter()
                .map(|h| h.join().expect("rank thread"))
                .collect();
            let rounds = stats[0].rounds.max(1) as f64;
            let sum_us = |f: &dyn Fn(&TransportStats) -> u64| -> f64 {
                stats.iter().map(|s| f(s)).sum::<u64>() as f64 / rounds / 1e3
            };
            TransportCell {
                rounds: stats[0].rounds,
                wire_us_per_round: sum_us(&|s| s.pack_ns + s.unpack_ns),
                wait_us_per_round: sum_us(&|s| s.wait_ns),
                residual_us_per_round: sum_us(&|s| s.residual_wait_ns),
                bytes_per_round: stats.iter().map(|s| s.bytes_sent).sum::<u64>() as f64 / rounds,
                posts: stats.iter().map(|s| s.posts).sum(),
                polls: stats.iter().map(|s| s.polls).sum(),
            }
        };
        let tcp = run(false);
        let shm = if shm_supported { Some(run(true)) } else { None };
        (tcp, shm)
    };
    println!(
        "\n# transport ablation ({transport_t_ms} ms model time, 2 rank-local \
         engines, d_min = 5 steps)\n"
    );
    let mut tt = Table::new([
        "transport",
        "rounds",
        "wire [us/round]",
        "wait [us/round]",
        "resid [us/round]",
        "bytes/round",
        "posts/polls",
    ]);
    for (name, c) in std::iter::once(("tcp", &trans_tcp))
        .chain(trans_shm.iter().map(|c| ("shm", c)))
    {
        tt.add_row([
            name.to_string(),
            format!("{}", c.rounds),
            format!("{:.2}", c.wire_us_per_round),
            format!("{:.2}", c.wait_us_per_round),
            format!("{:.2}", c.residual_us_per_round),
            format!("{:.0}", c.bytes_per_round),
            format!("{}/{}", c.posts, c.polls),
        ]);
    }
    tt.print();
    let wire_wait = |c: &TransportCell| -> f64 {
        c.wire_us_per_round + c.wait_us_per_round + c.residual_us_per_round
    };
    if let Some(shm) = &trans_shm {
        if wire_wait(shm) >= wire_wait(&trans_tcp) {
            println!("WARNING: shm wire+wait per round did not beat tcp on this box/run");
        }
    } else {
        println!("(shm rings unsupported on this target — tcp cell only)");
    }

    // --- fault-recovery ablation: clean vs injected loopback -------------------
    // The same 2-rank loopback run, once clean and once under a seeded
    // fault plan (drops + a duplicate + one corrupted frame). The
    // reliability protocol must absorb every fault — bit-identical
    // train — and the cell records what that recovery costs in wall
    // time, retransmissions and recovered frames per round.
    struct FaultCell {
        rounds: u64,
        wall_ms: f64,
        retries: u64,
        frames_recovered: u64,
        corrupt_frames_dropped: u64,
        dup_frames_discarded: u64,
    }
    let fault_t_ms = if quick { 50.0 } else { 200.0 };
    let fault_plan_text = "seed=7,drop=0.2,dup=0.1,corrupt=5";
    let (fault_clean, fault_injected, fault_identical) = {
        use nsim::comm::{FaultInjector, FaultPlan, LoopbackTransport, Transport};
        use nsim::coordinator::build_microcircuit_sim;
        let run = |plan: Option<FaultPlan>| -> (FaultCell, Vec<(u64, u32)>) {
            let mut sim = build_microcircuit_sim(&RunSpec {
                scale: 0.02,
                n_ranks: 2,
                n_threads: 2,
                os_threads: 2,
                record_spikes: true,
                ..Default::default()
            });
            let inner: Box<dyn Transport> = Box::new(LoopbackTransport::new(2));
            let tr: Box<dyn Transport> = match plan {
                Some(p) => Box::new(FaultInjector::new(inner, p)),
                None => inner,
            };
            sim.set_transport(tr).expect("attach transport");
            let t0 = std::time::Instant::now();
            let res = sim.simulate(fault_t_ms);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let ts = sim.transport_stats().expect("transport stats");
            (
                FaultCell {
                    rounds: ts.rounds,
                    wall_ms,
                    retries: ts.retries,
                    frames_recovered: ts.frames_recovered,
                    corrupt_frames_dropped: ts.corrupt_frames_dropped,
                    dup_frames_discarded: ts.dup_frames_discarded,
                },
                res.spikes,
            )
        };
        let (clean, clean_spikes) = run(None);
        let plan = FaultPlan::parse(fault_plan_text).expect("bench fault plan");
        let (injected, injected_spikes) = run(Some(plan));
        (clean, injected, clean_spikes == injected_spikes)
    };
    println!(
        "\n# fault-recovery ablation ({fault_t_ms} ms model time, 2-rank loopback, \
         plan {fault_plan_text})\n"
    );
    let mut tf = Table::new([
        "run",
        "rounds",
        "wall [ms]",
        "retries",
        "recovered",
        "corrupt",
        "dups",
    ]);
    for (name, c) in [("clean", &fault_clean), ("injected", &fault_injected)] {
        tf.add_row([
            name.to_string(),
            format!("{}", c.rounds),
            format!("{:.1}", c.wall_ms),
            format!("{}", c.retries),
            format!("{}", c.frames_recovered),
            format!("{}", c.corrupt_frames_dropped),
            format!("{}", c.dup_frames_discarded),
        ]);
    }
    tf.print();
    if !fault_identical {
        println!("WARNING: fault injection changed the recorded train — determinism broken");
    }

    // --- end-to-end engine step ------------------------------------------------
    let e2e = {
        use nsim::util::timer::Phase;
        let e2e_t_ms = if quick { 50.0 } else { 100.0 };
        let (mut sim, _) = run_microcircuit(&RunSpec {
            scale: 0.1,
            t_model_ms: e2e_t_ms,
            t_presim_ms: 0.0,
            ..Default::default()
        });
        let s6 = bench_runs(1, if quick { 2 } else { 5 }, || {
            sim.simulate(e2e_t_ms);
        });
        // one instrumented run for the phase split + counters
        let res = sim.simulate(e2e_t_ms);
        let conn_bytes = sim.net.connection_memory_bytes();
        let dense_bytes = sim.net.dense_csr_memory_bytes();
        t.add_row([
            "engine, scale-0.1 circuit".to_string(),
            format!("RTF {:.2} (1 core)", s6.median() / (e2e_t_ms * 1e-3)),
            format!("{:.1} ms / {e2e_t_ms} ms model", s6.median() * 1e3),
        ]);
        (
            s6.median() / (e2e_t_ms * 1e-3),                   // RTF
            res.timers.get(Phase::Update).as_secs_f64() * 1e3, // ms
            res.timers.get(Phase::Communicate).as_secs_f64() * 1e3,
            res.timers.get(Phase::Deliver).as_secs_f64() * 1e3,
            res.timers.get(Phase::Other).as_secs_f64() * 1e3,
            conn_bytes as f64 / sim.net.n_synapses as f64, // bytes/synapse
            conn_bytes,
            dense_bytes,
            res.counters.deliver_skip_rate(),
        )
    };

    t.print();
    println!("\ntargets (DESIGN.md §7): update ≥ 10 M/s, delivery ≥ 5 M events/s");

    // --- trajectory record -------------------------------------------------
    let fmt_ms = |v: &[f64]| -> String {
        let items: Vec<String> = v.iter().map(|x| format!("{x:.4}")).collect();
        format!("[{}]", items.join(", "))
    };
    let sched_json = format!(
        "{{\n    \"os_threads\": 4,\n    \"serial_merge_static\": {{\n      \
         \"communicate_ms_per_thread\": {},\n      \"deliver_ms_per_thread\": {},\n      \
         \"idle_ms_per_thread\": {},\n      \"deliver_spread_ms\": {:.4}\n    }},\n    \
         \"pipelined_worksteal\": {{\n      \"communicate_ms_per_thread\": {},\n      \
         \"deliver_ms_per_thread\": {},\n      \"idle_ms_per_thread\": {},\n      \
         \"deliver_spread_ms\": {:.4},\n      \"tasks_stolen\": {}\n    }},\n    \
         \"all_threads_merge\": {},\n    \"deliver_spread_reduced\": {}\n  }}",
        fmt_ms(&sched_static.comm_ms),
        fmt_ms(&sched_static.deliver_ms),
        fmt_ms(&sched_static.idle_ms),
        static_spread,
        fmt_ms(&sched_pipe.comm_ms),
        fmt_ms(&sched_pipe.deliver_ms),
        fmt_ms(&sched_pipe.idle_ms),
        pipe_spread,
        sched_pipe.stolen,
        all_threads_merge,
        pipe_spread < static_spread,
    );
    let slice_cell_json = |s: &SliceAblation| -> String {
        format!(
            "{{\n      \"merge_slice_max_packets\": {},\n      \
             \"merge_slice_min_packets\": {},\n      \
             \"merge_slice_span\": {},\n      \
             \"merge_slice_imbalance\": {:.4},\n      \
             \"deliver_spread_ms\": {:.4},\n      \
             \"tasks_local\": {},\n      \"tasks_stolen\": {}\n    }}",
            s.merge_max,
            s.merge_min,
            s.merge_max - s.merge_min,
            s.imbalance,
            s.deliver_spread_ms,
            s.local,
            s.stolen,
        )
    };
    let clustered_json = format!(
        "{{\n    \"os_threads\": 4,\n    \"equal_width\": {},\n    \
         \"adaptive\": {},\n    \"merge_span_reduced\": {},\n    \
         \"deliver_spread_no_worse\": {}\n  }}",
        slice_cell_json(&slice_eq),
        slice_cell_json(&slice_ad),
        span_ad < span_eq,
        slice_ad.deliver_spread_ms <= slice_eq.deliver_spread_ms,
    );
    let transport_cell_json = |c: &TransportCell| -> String {
        format!(
            "{{\n      \"rounds\": {},\n      \"wire_us_per_round\": {:.4},\n      \
             \"wait_us_per_round\": {:.4},\n      \"residual_us_per_round\": {:.4},\n      \
             \"bytes_per_round\": {:.1},\n      \"posts\": {},\n      \"polls\": {}\n    }}",
            c.rounds,
            c.wire_us_per_round,
            c.wait_us_per_round,
            c.residual_us_per_round,
            c.bytes_per_round,
            c.posts,
            c.polls,
        )
    };
    let transport_json = format!(
        "{{\n    \"t_model_ms\": {},\n    \"ranks\": 2,\n    \"shm_supported\": {},\n    \
         \"tcp\": {},\n    \"shm\": {},\n    \"shm_wire_wait_below_tcp\": {}\n  }}",
        transport_t_ms,
        shm_supported,
        transport_cell_json(&trans_tcp),
        trans_shm
            .as_ref()
            .map(|c| transport_cell_json(c))
            .unwrap_or_else(|| "null".to_string()),
        trans_shm
            .as_ref()
            .map(|c| wire_wait(c) < wire_wait(&trans_tcp))
            .unwrap_or(false),
    );
    let fault_cell_json = |c: &FaultCell| -> String {
        format!(
            "{{\n      \"rounds\": {},\n      \"wall_ms\": {:.3},\n      \
             \"retries\": {},\n      \"frames_recovered\": {},\n      \
             \"corrupt_frames_dropped\": {},\n      \"dup_frames_discarded\": {}\n    }}",
            c.rounds,
            c.wall_ms,
            c.retries,
            c.frames_recovered,
            c.corrupt_frames_dropped,
            c.dup_frames_discarded,
        )
    };
    let fault_json = format!(
        "{{\n    \"t_model_ms\": {},\n    \"ranks\": 2,\n    \"plan\": \"{}\",\n    \
         \"clean\": {},\n    \"injected\": {},\n    \"bit_identical\": {},\n    \
         \"recovery_wall_overhead\": {:.4}\n  }}",
        fault_t_ms,
        fault_plan_text,
        fault_cell_json(&fault_clean),
        fault_cell_json(&fault_injected),
        fault_identical,
        fault_injected.wall_ms / fault_clean.wall_ms.max(1e-9),
    );
    let kernel_json = format!(
        "{{\n    \"subthreshold_ns_per_update\": {{ \"scalar\": {:.3}, \"vector\": {:.3}, \
         \"speedup\": {:.4} }},\n    \
         \"high_rate_ns_per_update\": {{ \"scalar\": {:.3}, \"vector\": {:.3}, \
         \"speedup\": {:.4} }}\n  }}",
        scalar_sub_ns,
        vector_sub_ns,
        scalar_sub_ns / vector_sub_ns.max(1e-12),
        scalar_hot_ns,
        vector_hot_ns,
        scalar_hot_ns / vector_hot_ns.max(1e-12),
    );
    let json = format!(
        "{{\n  \"bench\": \"bench_micro\",\n  \"quick\": {},\n  \"engine\": {{\n    \
         \"rtf_scale01_1core\": {:.4},\n    \"phase_ms\": {{ \"update\": {:.3}, \
         \"communicate\": {:.3}, \"deliver\": {:.3}, \"other\": {:.3} }},\n    \
         \"deliver_scan_skip_rate\": {:.6}\n  }},\n  \"update_kernel_ablation\": {},\n  \
         \"delivery_ablation_ns_per_event\": {{\n    \
         \"dense_csr_sorted\": {:.3},\n    \"dense_csr_unsorted\": {:.3},\n    \
         \"compressed_plan\": {:.3},\n    \"plan_speedup_vs_csr\": {:.3}\n  }},\n  \
         \"connection_memory\": {{\n    \"bytes_per_synapse\": {:.3},\n    \
         \"plan_bytes\": {},\n    \"dense_csr_bytes\": {},\n    \
         \"compression\": {:.4}\n  }},\n  \
         \"threaded_schedule_ablation\": {},\n  \
         \"clustered_activity_ablation\": {},\n  \
         \"transport_ablation\": {},\n  \
         \"fault_recovery_ablation\": {},\n  \
         \"interval_sweep_dmin1_skip_rate\": {:.6}\n}}\n",
        quick,
        e2e.0,
        e2e.1,
        e2e.2,
        e2e.3,
        e2e.4,
        e2e.8,
        kernel_json,
        csr_ns_per_event,
        csr_unsorted_ns_per_event,
        plan_ns_per_event,
        csr_ns_per_event / plan_ns_per_event.max(1e-12),
        e2e.5,
        e2e.6,
        e2e.7,
        1.0 - e2e.6 as f64 / e2e.7 as f64,
        sched_json,
        clustered_json,
        transport_json,
        fault_json,
        sweep_skip_rate,
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_micro.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\ntrajectory record written to {path}"),
        Err(e) => println!("\nWARNING: could not write {path}: {e}"),
    }
}
