//! Engine micro-benchmarks (§Perf baseline) + model ablation:
//!
//! * neuron-update throughput (exact integration incl. Poisson drive),
//! * spike-delivery throughput (target-table scan + ring-buffer scatter),
//! * ring-buffer row read/clear bandwidth,
//! * Poisson sampling rate,
//! * ablation: `iaf_psc_exp` vs `iaf_psc_delta` update cost (what the
//!   synaptic-current dynamics cost, DESIGN.md ablation),
//! * end-to-end engine step at scale 0.1.
//!
//! Run: `cargo bench --bench bench_micro`. Results feed EXPERIMENTS.md
//! §Perf (before/after table).

use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::engine::RingBuffer;
use nsim::models::{IafParams, IafPscDelta, IafPscExp, NeuronState, PoissonSource, RESOLUTION_MS};
use nsim::util::rng::Pcg64;
use nsim::util::table::Table;
use nsim::util::timer::bench_runs;

fn main() {
    println!("# engine micro-benchmarks (1 core, this container)\n");
    let mut t = Table::new(["benchmark", "throughput", "per-op"]);

    // --- neuron update ----------------------------------------------------
    let n = 100_000;
    let model = IafPscExp::new(&IafParams::default(), RESOLUTION_MS);
    let mut st = NeuronState::with_len(n);
    let mut rng = Pcg64::seed_from_u64(1);
    for i in 0..n {
        st.v_m[i] = rng.uniform() * 20.0 - 5.0;
    }
    let in_ex = vec![5.0; n];
    let in_in = vec![-2.0; n];
    let mut spikes = Vec::new();
    let s = bench_runs(3, 10, || {
        spikes.clear();
        model.update_chunk(&mut st, 0, n, &in_ex, &in_in, &mut spikes);
    });
    let per_op = s.median() / n as f64;
    t.add_row([
        "neuron update (iaf_psc_exp)".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op),
        format!("{:.2} ns", per_op * 1e9),
    ]);

    // --- ablation: delta model ---------------------------------------------
    let delta = IafPscDelta::new(&IafParams::default(), RESOLUTION_MS);
    let mut st2 = NeuronState::with_len(n);
    let s2 = bench_runs(3, 10, || {
        spikes.clear();
        delta.update_chunk(&mut st2, 0, n, &in_ex, &in_in, &mut spikes);
    });
    let per_op2 = s2.median() / n as f64;
    t.add_row([
        "neuron update (iaf_psc_delta)".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op2),
        format!("{:.2} ns", per_op2 * 1e9),
    ]);

    // --- Poisson sampling ---------------------------------------------------
    let src = PoissonSource::new(12_800.0, 87.8, RESOLUTION_MS);
    let mut acc = vec![0.0; n];
    let mut prng = Pcg64::seed_from_u64(2);
    let s3 = bench_runs(3, 10, || {
        src.sample_into(&mut prng, &mut acc);
    });
    let per_op3 = s3.median() / n as f64;
    t.add_row([
        "poisson drive sample".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op3),
        format!("{:.2} ns", per_op3 * 1e9),
    ]);

    // --- ring buffer ---------------------------------------------------------
    let mut rb = RingBuffer::new(n, 80);
    let mut row = vec![0.0; n];
    let s4 = bench_runs(3, 20, || {
        rb.take_row_into(3, &mut row);
    });
    t.add_row([
        "ring-buffer row read+clear".to_string(),
        format!("{:.1} GB/s", n as f64 * 8.0 / s4.median() / 1e9),
        format!("{:.2} ns/neuron", s4.median() / n as f64 * 1e9),
    ]);

    // --- delivery (+ row-sort ablation) ---------------------------------------
    // realistic target table: one full-scale-density source population
    {
        use nsim::connection::{TargetTable, TargetTableBuilder};
        let n_src = 10_000u32;
        let out_deg = 1000usize;
        let build = |sorted: bool| -> TargetTable {
            let mut b = TargetTableBuilder::new(n_src as usize);
            let mut crng = Pcg64::seed_from_u64(3);
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b.count(src);
                }
            }
            b.start_fill();
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b.push(
                        src,
                        crng.below(n as u64) as u32,
                        if crng.uniform() < 0.8 { 87.8 } else { -351.2 },
                        1 + crng.below(60) as u16,
                    );
                }
            }
            if sorted {
                b.finish()
            } else {
                b.finish_unsorted()
            }
        };
        let mut crng = Pcg64::seed_from_u64(4);
        let spikers: Vec<u32> = (0..200).map(|_| crng.below(n_src as u64) as u32).collect();
        for (sorted, label) in [
            (true, "spike delivery (sorted rows)"),
            (false, "spike delivery (unsorted, ablation)"),
        ] {
            let table = build(sorted);
            let mut ring_ex = RingBuffer::new(n, 80);
            let mut ring_in = RingBuffer::new(n, 80);
            let events_per_iter = spikers.iter().map(|&s| table.out_degree(s)).sum::<u64>();
            let s5 = bench_runs(3, 20, || {
                for &gid in &spikers {
                    let (tgts, ws, ds) = table.outgoing(gid);
                    for i in 0..tgts.len() {
                        let w = ws[i];
                        if w >= 0.0 {
                            ring_ex.add(7 + ds[i] as u64, tgts[i], w);
                        } else {
                            ring_in.add(7 + ds[i] as u64, tgts[i], w);
                        }
                    }
                }
            });
            let per_ev = s5.median() / events_per_iter as f64;
            t.add_row([
                label.to_string(),
                format!("{:.1} M events/s", 1e-6 / per_ev),
                format!("{:.2} ns", per_ev * 1e9),
            ]);
        }
    }

    // --- min-delay interval sweep ----------------------------------------------
    // Same connectivity and drive, delays scaled so d_min = 1, 5, 15 steps:
    // the interval cycle runs steps/d_min communication rounds, so the
    // communicate phase (and its per-round fixed cost) shrinks accordingly
    // while update work is unchanged. Feeds the BENCH_*.json trajectories.
    {
        use nsim::engine::{Decomposition, SimConfig, Simulator};
        use nsim::models::ModelKind;
        use nsim::network::rules::{weight_dist, ConnRule};
        use nsim::network::{build, Dist, NetworkSpec};
        use nsim::util::table::fmt_count;
        use nsim::util::timer::Phase;

        println!("\n# min-delay interval sweep (500 ms model time, 4 VPs on 2 ranks)\n");
        let mut ti = Table::new([
            "d_min [steps]",
            "comm rounds",
            "bytes sent",
            "update [ms]",
            "communicate [ms]",
            "deliver [ms]",
        ]);
        for d_min in [1u16, 5, 15] {
            let d_ms = d_min as f64 * RESOLUTION_MS;
            let v0 = Dist::ClippedNormal {
                mean: -58.0,
                std: 5.0,
                lo: f64::NEG_INFINITY,
                hi: -50.000001,
            };
            let mut s = NetworkSpec::new(RESOLUTION_MS, 42);
            let e = s.add_population(
                "E",
                2000,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            let i = s.add_population(
                "I",
                500,
                ModelKind::IafPscExp,
                nsim::models::IafParams::default(),
                v0,
                10_000.0,
                87.8,
            );
            // delays: d_min on the inhibitory loop, 3·d_min elsewhere
            s.connect(
                e,
                e,
                ConnRule::FixedTotalNumber { n: 20_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(d_ms * 3.0),
            );
            s.connect(
                e,
                i,
                ConnRule::FixedTotalNumber { n: 5_000 },
                weight_dist(87.8, 0.1),
                Dist::Const(d_ms * 3.0),
            );
            s.connect(
                i,
                e,
                ConnRule::FixedTotalNumber { n: 5_000 },
                weight_dist(-351.2, 0.1),
                Dist::Const(d_ms),
            );
            s.connect(
                i,
                i,
                ConnRule::FixedTotalNumber { n: 1_250 },
                weight_dist(-351.2, 0.1),
                Dist::Const(d_ms),
            );
            let net = build(&s, Decomposition::new(2, 2));
            assert_eq!(net.min_delay_steps, d_min);
            let mut sim = Simulator::new(
                net,
                SimConfig {
                    record_spikes: false,
                    os_threads: 1,
                },
            );
            let res = sim.simulate(500.0);
            ti.add_row([
                format!("{d_min}"),
                // VP 0 of rank 0: rounds this rank participated in
                format!("{}", res.per_vp_counters[0].comm_rounds),
                fmt_count(res.counters.comm_bytes_sent),
                format!("{:.2}", res.timers.get(Phase::Update).as_secs_f64() * 1e3),
                format!(
                    "{:.3}",
                    res.timers.get(Phase::Communicate).as_secs_f64() * 1e3
                ),
                format!("{:.2}", res.timers.get(Phase::Deliver).as_secs_f64() * 1e3),
            ]);
        }
        ti.print();
        println!("(5000 steps → 5000 / d_min rounds: communicate's latency share falls)");
    }

    // --- end-to-end engine step ------------------------------------------------
    {
        let (mut sim, _) = run_microcircuit(&RunSpec {
            scale: 0.1,
            t_model_ms: 100.0,
            t_presim_ms: 0.0,
            ..Default::default()
        });
        let s6 = bench_runs(1, 5, || {
            sim.simulate(100.0);
        });
        t.add_row([
            "engine, scale-0.1 circuit".to_string(),
            format!("RTF {:.2} (1 core)", s6.median() / 0.1),
            format!("{:.1} ms / 100 ms model", s6.median() * 1e3),
        ]);
    }

    t.print();
    println!("\ntargets (DESIGN.md §7): update ≥ 10 M/s, delivery ≥ 5 M events/s");
}
