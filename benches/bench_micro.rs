//! Engine micro-benchmarks (§Perf baseline) + model ablation:
//!
//! * neuron-update throughput (exact integration incl. Poisson drive),
//! * spike-delivery throughput (target-table scan + ring-buffer scatter),
//! * ring-buffer row read/clear bandwidth,
//! * Poisson sampling rate,
//! * ablation: `iaf_psc_exp` vs `iaf_psc_delta` update cost (what the
//!   synaptic-current dynamics cost, DESIGN.md ablation),
//! * end-to-end engine step at scale 0.1.
//!
//! Run: `cargo bench --bench bench_micro`. Results feed EXPERIMENTS.md
//! §Perf (before/after table).

use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::engine::RingBuffer;
use nsim::models::{IafParams, IafPscDelta, IafPscExp, NeuronState, PoissonSource, RESOLUTION_MS};
use nsim::util::rng::Pcg64;
use nsim::util::table::Table;
use nsim::util::timer::bench_runs;

fn main() {
    println!("# engine micro-benchmarks (1 core, this container)\n");
    let mut t = Table::new(["benchmark", "throughput", "per-op"]);

    // --- neuron update ----------------------------------------------------
    let n = 100_000;
    let model = IafPscExp::new(&IafParams::default(), RESOLUTION_MS);
    let mut st = NeuronState::with_len(n);
    let mut rng = Pcg64::seed_from_u64(1);
    for i in 0..n {
        st.v_m[i] = rng.uniform() * 20.0 - 5.0;
    }
    let in_ex = vec![5.0; n];
    let in_in = vec![-2.0; n];
    let mut spikes = Vec::new();
    let s = bench_runs(3, 10, || {
        spikes.clear();
        model.update_chunk(&mut st, 0, n, &in_ex, &in_in, &mut spikes);
    });
    let per_op = s.median() / n as f64;
    t.add_row([
        "neuron update (iaf_psc_exp)".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op),
        format!("{:.2} ns", per_op * 1e9),
    ]);

    // --- ablation: delta model ---------------------------------------------
    let delta = IafPscDelta::new(&IafParams::default(), RESOLUTION_MS);
    let mut st2 = NeuronState::with_len(n);
    let s2 = bench_runs(3, 10, || {
        spikes.clear();
        delta.update_chunk(&mut st2, 0, n, &in_ex, &in_in, &mut spikes);
    });
    let per_op2 = s2.median() / n as f64;
    t.add_row([
        "neuron update (iaf_psc_delta)".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op2),
        format!("{:.2} ns", per_op2 * 1e9),
    ]);

    // --- Poisson sampling ---------------------------------------------------
    let src = PoissonSource::new(12_800.0, 87.8, RESOLUTION_MS);
    let mut acc = vec![0.0; n];
    let mut prng = Pcg64::seed_from_u64(2);
    let s3 = bench_runs(3, 10, || {
        src.sample_into(&mut prng, &mut acc);
    });
    let per_op3 = s3.median() / n as f64;
    t.add_row([
        "poisson drive sample".to_string(),
        format!("{:.1} M/s", 1e-6 / per_op3),
        format!("{:.2} ns", per_op3 * 1e9),
    ]);

    // --- ring buffer ---------------------------------------------------------
    let mut rb = RingBuffer::new(n, 80);
    let mut row = vec![0.0; n];
    let s4 = bench_runs(3, 20, || {
        rb.take_row_into(3, &mut row);
    });
    t.add_row([
        "ring-buffer row read+clear".to_string(),
        format!("{:.1} GB/s", n as f64 * 8.0 / s4.median() / 1e9),
        format!("{:.2} ns/neuron", s4.median() / n as f64 * 1e9),
    ]);

    // --- delivery (+ row-sort ablation) ---------------------------------------
    // realistic target table: one full-scale-density source population
    {
        use nsim::connection::{TargetTable, TargetTableBuilder};
        let n_src = 10_000u32;
        let out_deg = 1000usize;
        let build = |sorted: bool| -> TargetTable {
            let mut b = TargetTableBuilder::new(n_src as usize);
            let mut crng = Pcg64::seed_from_u64(3);
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b.count(src);
                }
            }
            b.start_fill();
            for src in 0..n_src {
                for _ in 0..out_deg {
                    b.push(
                        src,
                        crng.below(n as u64) as u32,
                        if crng.uniform() < 0.8 { 87.8 } else { -351.2 },
                        1 + crng.below(60) as u16,
                    );
                }
            }
            if sorted {
                b.finish()
            } else {
                b.finish_unsorted()
            }
        };
        let mut crng = Pcg64::seed_from_u64(4);
        let spikers: Vec<u32> = (0..200).map(|_| crng.below(n_src as u64) as u32).collect();
        for (sorted, label) in [(true, "spike delivery (sorted rows)"), (false, "spike delivery (unsorted, ablation)")] {
            let table = build(sorted);
            let mut ring_ex = RingBuffer::new(n, 80);
            let mut ring_in = RingBuffer::new(n, 80);
            let events_per_iter = spikers.iter().map(|&s| table.out_degree(s)).sum::<u64>();
            let s5 = bench_runs(3, 20, || {
                for &gid in &spikers {
                    let (tgts, ws, ds) = table.outgoing(gid);
                    for i in 0..tgts.len() {
                        let w = ws[i];
                        if w >= 0.0 {
                            ring_ex.add(7 + ds[i] as u64, tgts[i], w);
                        } else {
                            ring_in.add(7 + ds[i] as u64, tgts[i], w);
                        }
                    }
                }
            });
            let per_ev = s5.median() / events_per_iter as f64;
            t.add_row([
                label.to_string(),
                format!("{:.1} M events/s", 1e-6 / per_ev),
                format!("{:.2} ns", per_ev * 1e9),
            ]);
        }
    }

    // --- end-to-end engine step ------------------------------------------------
    {
        let (mut sim, _) = run_microcircuit(&RunSpec {
            scale: 0.1,
            t_model_ms: 100.0,
            t_presim_ms: 0.0,
            ..Default::default()
        });
        let s6 = bench_runs(1, 5, || {
            sim.simulate(100.0);
        });
        t.add_row([
            "engine, scale-0.1 circuit".to_string(),
            format!("RTF {:.2} (1 core)", s6.median() / 0.1),
            format!("{:.1} ms / 100 ms model", s6.median() * 1e3),
        ]);
    }

    t.print();
    println!("\ntargets (DESIGN.md §7): update ≥ 10 M/s, delivery ≥ 5 M events/s");
}
