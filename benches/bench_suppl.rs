//! Bench E5+E6 — regenerates the supplementary results:
//!
//! * **LLC miss rates** (Suppl. "Low level performance measurements"):
//!   43 % sequential-64 vs 25 % distant-64, from the cache model over a
//!   100 s-of-model-time workload (the supplement's protocol);
//! * **Suppl. Fig 1** raster statistics: asynchronous irregular activity
//!   with cell-type specific rates (engine run, 60 % neuron selection).
//!
//! Run: `cargo bench --bench bench_suppl`.

use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::hw::calib::anchors;
use nsim::hw::{predict, Calib, HwConfig, Machine, Placement, Workload};
use nsim::network::microcircuit::{FULL_MEAN_RATES, POP_NAMES};
use nsim::stats::{self, raster::RasterData};
use nsim::util::json::{write_file, Json};
use nsim::util::table::Table;

fn main() {
    println!("# Supplementary results\n");

    // --- LLC miss rates -------------------------------------------------
    println!("## LLC miss rates (perf-stat analogue, 100 s model time)");
    let w = Workload::microcircuit_full();
    let c = Calib::default();
    let m1 = Machine::epyc_rome_7702(1);
    let seq64 = predict(&w, &HwConfig::new(m1, Placement::Sequential, 64), &c);
    let dist64 = predict(&w, &HwConfig::new(m1, Placement::Distant, 64), &c);
    let mut t = Table::new(["config", "model LLC miss", "paper"]);
    t.add_row([
        "sequential-64".to_string(),
        format!("{:.1} %", seq64.llc_miss * 100.0),
        format!("{:.0} %", anchors::LLC_MISS_SEQ_64 * 100.0),
    ]);
    t.add_row([
        "distant-64".to_string(),
        format!("{:.1} %", dist64.llc_miss * 100.0),
        format!("{:.0} %", anchors::LLC_MISS_DIST_64 * 100.0),
    ]);
    t.print();
    assert!((seq64.llc_miss - anchors::LLC_MISS_SEQ_64).abs() < 0.08);
    assert!((dist64.llc_miss - anchors::LLC_MISS_DIST_64).abs() < 0.08);
    assert!(seq64.llc_miss > dist64.llc_miss);

    // --- raster / activity ----------------------------------------------
    println!("\n## Suppl. Fig 1 — activity statistics (engine, scale 0.1)");
    let spec = RunSpec {
        scale: 0.1,
        t_model_ms: 1_000.0,
        record_spikes: true,
        ..Default::default()
    };
    let (sim, res) = run_microcircuit(&spec);
    let rates = stats::population_rates(&sim.net.spec, &res.spikes, res.t_model_ms);
    let cvs = stats::population_cv_isi(&sim.net.spec, &res.spikes);
    let mut t = Table::new(["population", "rate [Hz]", "ref [Hz]", "CV ISI", "sync"]);
    let mut json_rows = Vec::new();
    for p in 0..8 {
        let si = stats::synchrony_index(&sim.net.spec, &res.spikes, p, res.t_model_ms, 3.0);
        t.add_row([
            POP_NAMES[p].to_string(),
            format!("{:.2}", rates[p]),
            format!("{:.2}", FULL_MEAN_RATES[p]),
            format!("{:.2}", cvs[p]),
            format!("{:.1}", si),
        ]);
        let mut o = Json::obj();
        o.set("pop", Json::from(POP_NAMES[p]))
            .set("rate_hz", Json::from(rates[p]))
            .set("ref_hz", Json::from(FULL_MEAN_RATES[p]))
            .set("cv_isi", Json::from(cvs[p]))
            .set("synchrony", Json::from(si));
        json_rows.push(o);
        // asynchronous irregular, cell-type specific (loose bands)
        assert!(
            rates[p] > 0.1 && rates[p] < 3.0 * FULL_MEAN_RATES[p] + 2.0,
            "pop {p} rate {}",
            rates[p]
        );
    }
    t.print();

    // the 200 ms / 60 % raster of the figure
    let raster = RasterData::build(
        &sim.net.spec,
        &res.spikes,
        spec.t_presim_ms + 100.0,
        spec.t_presim_ms + 300.0,
        0.6,
        spec.seed,
    );
    println!(
        "\nraster selection: {} of {} neurons (60 %), {} spikes in 200 ms",
        raster.rows.len(),
        sim.net.n_neurons,
        raster.n_spikes()
    );
    assert!(raster.n_spikes() > 100, "raster must show activity");

    let mut out = Json::obj();
    out.set("llc_miss_seq64", Json::from(seq64.llc_miss))
        .set("llc_miss_dist64", Json::from(dist64.llc_miss))
        .set("activity", Json::Arr(json_rows))
        .set("raster_rows", Json::from(raster.rows.len()))
        .set("raster_spikes", Json::from(raster.n_spikes()));
    write_file("bench_results/suppl.json", &out).expect("write json");
    println!("\nOK — wrote bench_results/suppl.json");
}
