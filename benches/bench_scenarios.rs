//! Scenario sweep bench target + the CI benchmark-trajectory gate.
//!
//! Runs the declarative scenario grid (`coordinator::scenario`) across
//! the d_min / scale / threads / schedule axes, writes the versioned
//! `BENCH_scenarios.json` trajectory record to the repository root, and
//! — with `--check <baseline.json>` — compares the run against a
//! committed baseline with per-metric tolerance bands, exiting non-zero
//! on regression. This is what turns the `BENCH_*.json` files from
//! write-only artifacts into an enforced performance trajectory.
//!
//! Run:
//!
//! ```text
//! cargo bench --bench bench_scenarios                # full grid
//! cargo bench --bench bench_scenarios -- --quick     # CI sizing
//! cargo bench --bench bench_scenarios -- --quick --check ci/baseline_scenarios.json
//! ```
//!
//! Baseline refresh (after a change that legitimately moves the
//! trajectory): run the quick sweep on the reference machine and commit
//! the fresh record as `rust/ci/baseline_scenarios.json` — see
//! README §"Scenario sweeps & the benchmark trajectory".

use nsim::coordinator::scenario::{
    enforce_schedule_consistency, gate_against_file, run_sweep, summary_table, ScenarioSpec,
};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let check_pos = argv.iter().position(|a| a == "--check");
    let check = check_pos.and_then(|i| argv.get(i + 1)).cloned();
    if check_pos.is_some() && check.is_none() {
        // `--check` with the path missing must not silently skip the gate
        eprintln!("--check requires a baseline path");
        std::process::exit(2);
    }
    let spec = if quick {
        ScenarioSpec::quick()
    } else {
        ScenarioSpec::full()
    };
    println!(
        "# scenario sweep — {} sizing, {} cells, T_model {} ms\n",
        if quick { "QUICK (CI)" } else { "full" },
        spec.expand().len(),
        spec.t_model_ms
    );
    let rec = run_sweep(&spec, quick);
    println!();
    summary_table(&rec).print();

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scenarios.json");
    match nsim::util::json::write_file(path, &rec.to_json()) {
        Ok(()) => println!("\ntrajectory record written to {path}"),
        Err(e) => println!("\nWARNING: could not write {path}: {e}"),
    }

    // schedule-consistency gate, baseline-free (the record is written
    // first so the CI artifact survives a failure): cells that differ
    // only in the schedule axis (static / pipelined / adaptive) must
    // report identical deterministic counters — an adaptive cell
    // drifting away from its static sibling fails the job even while
    // the committed baseline is a bootstrap placeholder
    if !enforce_schedule_consistency(&rec) {
        std::process::exit(1);
    }

    if let Some(baseline) = check {
        let rep = match gate_against_file(&rec, &baseline) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cannot read baseline {baseline}: {e}");
                std::process::exit(2);
            }
        };
        println!();
        print!("{}", rep.render());
        if !rep.ok() {
            println!("regression gate FAILED against {baseline}");
            std::process::exit(1);
        }
        println!("regression gate passed against {baseline}");
    }
}
