//! Bench E1+E2 — regenerates **Fig 1b**: strong scaling of the
//! microcircuit on the modelled EPYC node(s), both placing schemes, RTF
//! curve (top panel) and per-phase fractions (bottom panels).
//!
//! The workload is *measured* by a real engine run (scaled circuit,
//! counts extrapolated per model-second are scale-exact for updates and
//! within sampling error for events), then projected by the calibrated
//! hardware model. Every row the paper plots is printed; paper anchor
//! values are attached where the paper states them.
//!
//! Run: `cargo bench --bench bench_fig1b` (plain-binary harness; the
//! offline toolchain has no criterion).

use nsim::coordinator::scaling::{paper_thread_counts, strong_scaling};
use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::hw::{Calib, Placement, Workload};
use nsim::util::json::{write_file, Json};
use nsim::util::table::Table;

fn main() {
    println!("# Fig 1b — strong scaling (sequential + distant placing)\n");

    // 1) measure the workload with a real engine run (scale 0.1, 1 s)
    let (sim, res) = run_microcircuit(&RunSpec {
        scale: 0.1,
        t_model_ms: 1_000.0,
        ..Default::default()
    });
    let measured = Workload::from_sim(
        sim.net.n_neurons,
        &res.counters,
        res.t_model_ms,
        sim.net.decomp.n_ranks,
    );
    println!(
        "engine measurement at scale 0.1: {:.3e} updates/s, {:.3e} events/s (RTF {:.2} on 1 core here)",
        measured.updates_per_s, measured.syn_events_per_s, res.rtf
    );

    // 2) canonical full-scale workload for the paper projection
    let w = Workload::microcircuit_full();
    println!(
        "full-scale workload (closed form): {:.3e} updates/s, {:.3e} events/s\n",
        w.updates_per_s, w.syn_events_per_s
    );

    let calib = Calib::default();
    let mut out = Json::obj();
    for placement in [Placement::Sequential, Placement::Distant] {
        let result = strong_scaling(&w, &calib, placement, None);
        println!("## {} placing (threads → RTF / phase fractions)", placement.name());
        let mut t = Table::new([
            "threads",
            "RTF",
            "update",
            "deliver",
            "communicate",
            "other",
            "paper",
        ]);
        for r in &result.rows {
            let anchor = match (placement, r.threads) {
                (Placement::Sequential, 128) => "0.70",
                (Placement::Sequential, 256) => "0.59",
                (Placement::Sequential, 1) => "~87",
                (Placement::Distant, 64) => "<1 (sub-realtime)",
                (Placement::Distant, 33) => "jump (L3 shared)",
                _ => "",
            };
            // print the subset of rows the figure annotates + powers of 2
            let show = r.threads.is_power_of_two()
                || matches!(r.threads, 33 | 48 | 96 | 256)
                || !anchor.is_empty();
            if !show {
                continue;
            }
            let f = r.pred.fractions();
            t.add_row([
                r.threads.to_string(),
                format!("{:.3}", r.pred.rtf),
                format!("{:.3}", f[0]),
                format!("{:.3}", f[1]),
                format!("{:.3}", f[2]),
                format!("{:.3}", f[3]),
                anchor.to_string(),
            ]);
        }
        t.print();
        println!(
            "rows: {} (full curve in fig1b.json); sub-realtime from {:?}; best RTF {:.3}\n",
            paper_thread_counts(placement).len(),
            result.first_subrealtime(),
            result.best_rtf()
        );
        out.set(placement.name(), result.to_json());
    }

    // shape assertions (the bench fails loudly if the reproduction breaks)
    let seq = strong_scaling(&w, &calib, Placement::Sequential, None);
    assert!(seq.at(128).unwrap().pred.rtf < 1.0, "single node sub-realtime");
    assert!(seq.at(256).unwrap().pred.rtf < seq.at(128).unwrap().pred.rtf);
    let dist = strong_scaling(&w, &calib, Placement::Distant, None);
    assert!(dist.at(33).unwrap().pred.rtf > dist.at(32).unwrap().pred.rtf);

    write_file("bench_results/fig1b.json", &out).expect("write json");
    println!("OK — wrote bench_results/fig1b.json");
}
