//! Bench E4 — regenerates **Table I**: realtime factor and energy per
//! synaptic event across the published systems (NEST/HPC, GeNN/GPU,
//! SpiNNaker, NeuronGPU) plus this work's calibrated model of the EPYC
//! node(s), in the paper's historical order.
//!
//! Run: `cargo bench --bench bench_table1`.

use nsim::coordinator::table1::{render, table1};
use nsim::hw::{Calib, PowerCalib, Workload};
use nsim::util::json::{write_file, Json};

fn main() {
    println!("# Table I — RTF and E/syn-event, historical sequence\n");
    let rows = table1(
        &Workload::microcircuit_full(),
        &Calib::default(),
        &PowerCalib::default(),
    );
    print!("{}", render(&rows));

    let ours: Vec<&_> = rows.iter().filter(|r| r.ours).collect();
    let best_lit = rows
        .iter()
        .filter(|r| !r.ours)
        .map(|r| r.rtf)
        .fold(f64::INFINITY, f64::min);
    println!("\nbest literature RTF: {best_lit:.2}");
    println!(
        "ours: single node {:.2} (paper 0.67–0.70), two nodes {:.2} (paper 0.53–0.59)",
        ours[0].rtf, ours[1].rtf
    );
    assert!(ours[0].rtf <= best_lit + 0.02, "lowest-RTF claim");
    assert!(ours[1].rtf < best_lit, "two-node record");

    let mut arr = Vec::new();
    for r in &rows {
        let mut o = Json::obj();
        o.set("rtf", Json::from(r.rtf))
            .set(
                "e_per_event_uj",
                r.e_per_event_uj.map(Json::from).unwrap_or(Json::Null),
            )
            .set("label", Json::from(r.label.clone()))
            .set("ours", Json::from(r.ours));
        arr.push(o);
    }
    write_file("bench_results/table1.json", &Json::Arr(arr)).expect("write json");
    println!("OK — wrote bench_results/table1.json");
}
