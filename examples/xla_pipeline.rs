//! Three-layer pipeline demo: run a small microcircuit with the update
//! phase executed by the AOT-compiled JAX/Pallas artifact via PJRT, and
//! verify spike-train equality against the native backend live.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example xla_pipeline -- --scale 0.01 --t-model 500
//! ```

use nsim::engine::{Decomposition, SimConfig, Simulator};
use nsim::network::build;
use nsim::network::microcircuit::{microcircuit, MicrocircuitConfig};
use nsim::runtime::XlaBackend;
use nsim::util::args::Args;
use nsim::util::table::fmt_count;

fn main() {
    let args = Args::parse();
    let scale = args.get_f64("scale", 0.01);
    let t_model = args.get_f64("t-model", 500.0);
    let cfg = MicrocircuitConfig {
        scale,
        seed: args.get_u64("seed", 55_374),
        ..Default::default()
    };
    println!("== three-layer pipeline: L1 pallas → L2 jax → HLO → L3 rust/PJRT ==");
    println!("microcircuit scale {scale}: {} neurons", cfg.n_neurons());

    let run = |use_xla: bool| {
        let net = build(&microcircuit(&cfg), Decomposition::serial());
        let sim_cfg = SimConfig {
            record_spikes: true,
            os_threads: 1,
            pipelined: true,
            adaptive: true,
            vectorize: true,
        };
        let mut sim = if use_xla {
            let be = XlaBackend::from_artifacts("artifacts", 2048, true)
                .expect("build with --features xla and run `make artifacts` first");
            Simulator::with_backend(net, sim_cfg, Box::new(be)).expect("iaf_psc_exp spec")
        } else {
            Simulator::new(net, sim_cfg)
        };
        let res = sim.simulate(t_model);
        (res, sim)
    };

    let t0 = std::time::Instant::now();
    let (native, _) = run(false);
    let t_native = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let (xla, _) = run(true);
    let t_xla = t1.elapsed().as_secs_f64();

    println!(
        "native backend: {} spikes in {:.2} s",
        fmt_count(native.counters.spikes_emitted),
        t_native
    );
    println!(
        "xla    backend: {} spikes in {:.2} s (per-step artifact dispatch)",
        fmt_count(xla.counters.spikes_emitted),
        t_xla
    );
    assert_eq!(
        native.spikes, xla.spikes,
        "spike trains must be identical across backends"
    );
    println!("\nspike trains IDENTICAL across backends ✓");
    println!(
        "(the XLA path proves the three layers compose; the native path is \
         the performance hot loop — see DESIGN.md §3)"
    );
}
