//! Energy study (Fig 1c): power traces of the paper's three node
//! configurations during 100 s of model time, with PDU-sampled
//! cumulative energy and the energy-per-synaptic-event metric.
//!
//! Prints an ASCII rendition of the figure's top panels (power vs time)
//! and bottom panel (cumulative energy), and writes the trace data as
//! CSV for plotting.
//!
//! ```bash
//! cargo run --release --example energy_study [-- --csv fig1c.csv]
//! ```

use nsim::coordinator::energy::energy_experiment;
use nsim::hw::{Calib, PowerCalib, Workload};
use nsim::util::args::Args;
use nsim::util::table::{Align, Table};

fn main() {
    let args = Args::parse();
    let t_model_s = args.get_f64("t-model-s", 100.0);
    let res = energy_experiment(
        &Workload::microcircuit_full(),
        &Calib::default(),
        &PowerCalib::default(),
        t_model_s,
        args.get_u64("seed", 1),
    );

    println!("== Fig 1c: power and energy, {t_model_s} s model time ==\n");
    let mut t = Table::new([
        "config",
        "threads",
        "RTF",
        "T_wall [s]",
        "P-base [kW]",
        "E_sim [kJ]",
        "E/event [µJ]",
    ])
    .align(0, Align::Left);
    for r in &res.rows {
        t.add_row([
            r.label.clone(),
            r.threads.to_string(),
            format!("{:.3}", r.pred.rtf),
            format!("{:.1}", r.t_wall_s),
            format!("{:.3}", (r.power_w - 200.0) / 1e3),
            format!("{:.1}", r.energy_j / 1e3),
            format!("{:.3}", r.e_per_event_uj),
        ]);
    }
    t.print();
    println!("\npaper: seq-64 0.21 kW | dist-64 0.39 kW | seq-128 0.33 kW above 0.2 kW baseline");
    println!("paper: 128 threads = shortest time AND smallest energy ✓\n");

    // ASCII power traces (sampled every ~5 s of wall time)
    for r in &res.rows {
        println!("power trace {} (W, PDU samples):", r.label);
        let max_p = 650.0;
        let n = r.trace.samples.len();
        let stride = (n / 24).max(1);
        for (i, &(t, p)) in r.trace.samples.iter().enumerate() {
            if i % stride != 0 {
                continue;
            }
            let bars = ((p / max_p) * 60.0) as usize;
            println!("  t={t:7.1}s {p:6.1} |{}", "#".repeat(bars));
        }
        println!();
    }

    if let Some(path) = args.get("csv") {
        let mut csv = String::from("config,t_s,power_w,cum_energy_j\n");
        for r in &res.rows {
            let cum = r.trace.cumulative_energy();
            let mut ci = 0;
            for &(t, p) in &r.trace.samples {
                let e = loop {
                    if ci + 1 < cum.len() && cum[ci].0 < t - 1.0 {
                        ci += 1;
                    } else {
                        break if ci < cum.len() { cum[ci].1 } else { 0.0 };
                    }
                };
                csv.push_str(&format!("{},{t:.1},{p:.1},{e:.1}\n", r.label));
            }
        }
        std::fs::write(path, csv).expect("write csv");
        println!("wrote {path}");
    }
}
