//! END-TO-END driver (DESIGN.md deliverable): run the microcircuit
//! through the real engine, report the paper's headline metric (RTF),
//! the per-phase breakdown, population rates against the reference, and
//! the calibrated hardware model's projection of this exact measured
//! workload onto the paper's 128-core node.
//!
//! At `--scale 1.0` this is the natural-density network: ~77k neurons,
//! ~299 M explicitly stored synapses (≈ 4.3 GB); build takes a couple of
//! minutes on one core. The default runs the full pipeline at scale 0.2
//! so the example finishes in minutes; EXPERIMENTS.md records a
//! full-scale run.
//!
//! ```bash
//! cargo run --release --example full_scale -- --scale 1.0 --t-model 10000
//! ```

use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::hw::{predict, Calib, HwConfig, Machine, Placement, Workload};
use nsim::network::microcircuit::{FULL_MEAN_RATES, POP_NAMES};
use nsim::stats;
use nsim::util::args::Args;
use nsim::util::table::{fmt_count, Align, Table};
use nsim::util::timer::Phase;

fn main() {
    let args = Args::parse();
    let spec = RunSpec {
        scale: args.get_f64("scale", 0.2),
        t_model_ms: args.get_f64("t-model", 2_000.0),
        t_presim_ms: args.get_f64("t-presim", 100.0),
        seed: args.get_u64("seed", 55_374),
        record_spikes: true,
        ..Default::default()
    };
    println!("== nsim end-to-end: microcircuit at scale {} ==", spec.scale);

    let t0 = std::time::Instant::now();
    let (sim, res) = run_microcircuit(&spec);
    println!(
        "network: {} neurons, {} synapses ({:.2} GB); total run {:.1} s",
        fmt_count(sim.net.n_neurons as u64),
        fmt_count(sim.net.n_synapses),
        sim.net.connection_memory_bytes() as f64 / 1e9,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "\nsimulated {:.1} s of model time in {:.2} s — engine-RTF {:.3} (1 core)",
        res.t_model_ms / 1e3,
        res.wall_s,
        res.rtf
    );
    println!(
        "spikes {} | recurrent syn events {} | external events {}",
        fmt_count(res.counters.spikes_emitted),
        fmt_count(res.counters.syn_events_delivered),
        fmt_count(res.counters.poisson_events)
    );
    let fr = res.timers.fractions();
    print!("phases:");
    for (i, ph) in Phase::ALL.iter().enumerate() {
        print!("  {} {:.1}%", ph.name(), fr[i] * 100.0);
    }
    println!();

    // --- activity validation (E7) -------------------------------------
    let rates = stats::population_rates(&sim.net.spec, &res.spikes, res.t_model_ms);
    let cvs = stats::population_cv_isi(&sim.net.spec, &res.spikes);
    let mut t = Table::new(["population", "rate [Hz]", "ref [Hz]", "CV ISI", "sync idx"])
        .align(0, Align::Left);
    for p in 0..8 {
        let si = stats::synchrony_index(&sim.net.spec, &res.spikes, p, res.t_model_ms, 3.0);
        t.add_row([
            POP_NAMES[p].to_string(),
            format!("{:.2}", rates[p]),
            format!("{:.2}", FULL_MEAN_RATES[p]),
            if cvs[p].is_nan() { "-".into() } else { format!("{:.2}", cvs[p]) },
            if si.is_nan() { "-".into() } else { format!("{:.1}", si) },
        ]);
    }
    println!();
    t.print();

    // --- project the measured workload onto the paper's node ----------
    // counts measured by THIS run, per model-second
    let w = Workload::from_sim(
        sim.net.n_neurons,
        &res.counters,
        res.t_model_ms,
        sim.net.decomp.n_ranks,
    );
    println!(
        "\nmeasured workload (per model-second): {:.2e} updates, {:.2e} syn events",
        w.updates_per_s, w.syn_events_per_s
    );
    let calib = Calib::default();
    let m1 = Machine::epyc_rome_7702(1);
    let mut t = Table::new(["config", "predicted RTF"]).align(0, Align::Left);
    for (label, placement, threads) in [
        ("sequential, 64 thr", Placement::Sequential, 64),
        ("sequential, 128 thr (full node)", Placement::Sequential, 128),
        ("distant, 64 thr", Placement::Distant, 64),
    ] {
        let p = predict(&w, &HwConfig::new(m1, placement, threads), &calib);
        t.add_row([label.to_string(), format!("{:.3}", p.rtf)]);
    }
    t.print();
    if spec.scale >= 0.999 {
        let p128 = predict(
            &w,
            &HwConfig::new(m1, Placement::Sequential, 128),
            &calib,
        );
        println!(
            "\nheadline: measured full-scale workload → RTF {:.3} on the modelled node \
             (paper: 0.70)",
            p128.rtf
        );
        assert!(p128.rtf < 1.0, "sub-realtime reproduction failed");
    }
}
