//! Raster plot (Suppl. Fig 1): simulate the microcircuit, select 60 % of
//! the neurons of each population, and render a 200 ms segment of the
//! spiking activity as ASCII art (plus CSV for real plotting).
//!
//! The expected picture: asynchronous irregular firing, L2/3e sparse,
//! L4/L5 denser — cell-type specific rates.
//!
//! ```bash
//! cargo run --release --example raster_plot -- --scale 0.1 --out raster.csv
//! ```

use nsim::coordinator::{run_microcircuit, RunSpec};
use nsim::network::microcircuit::POP_NAMES;
use nsim::stats::raster::RasterData;
use nsim::util::args::Args;

fn main() {
    let args = Args::parse();
    let spec = RunSpec {
        scale: args.get_f64("scale", 0.1),
        t_model_ms: args.get_f64("t-model", 400.0),
        record_spikes: true,
        ..Default::default()
    };
    let (sim, res) = run_microcircuit(&spec);
    let t0 = spec.t_presim_ms + 100.0;
    let t1 = t0 + 200.0; // "an arbitrary time segment of 200 ms"
    let raster = RasterData::build(&sim.net.spec, &res.spikes, t0, t1, 0.6, spec.seed);
    println!(
        "raster: {} neurons shown (60%), {} spikes in 200 ms",
        raster.rows.len(),
        raster.n_spikes()
    );

    // ASCII: one text row per ~N neurons, 100 columns for 200 ms
    let cols = 100usize;
    let rows_per_line = (raster.rows.len() / 40).max(1);
    let mut pop_mark = vec![String::new(); raster.rows.len().div_ceil(rows_per_line)];
    let mut grid = vec![vec![' '; cols]; pop_mark.len()];
    for r in &raster.rows {
        let line = r.y as usize / rows_per_line;
        if line >= grid.len() {
            continue;
        }
        pop_mark[line] = POP_NAMES[r.pop].to_string();
        for &t in &r.times_ms {
            let c = (((t - t0) / 200.0) * cols as f64) as usize;
            if c < cols {
                grid[line][c] = if r.pop % 2 == 0 { 'o' } else { 'x' };
            }
        }
    }
    println!("  (o = excitatory, x = inhibitory; 200 ms segment)");
    for (i, line) in grid.iter().enumerate() {
        println!("{:>6} |{}|", pop_mark[i], line.iter().collect::<String>());
    }

    let out = args.get_str("out", "raster.csv");
    std::fs::write(&out, raster.to_csv()).expect("write csv");
    println!("wrote {out}");
}
