//! Placement study (Fig 1b): sweep both thread-placing schemes across
//! the full thread range on the modelled node and print the RTF curves
//! with phase fractions, marking the paper's characteristic features
//! (linearity, super-linearity, the 33-thread jump, sub-realtime
//! crossings).
//!
//! ```bash
//! cargo run --release --example placement_study [-- --json fig1b.json]
//! ```

use nsim::coordinator::scaling::strong_scaling;
use nsim::hw::{Calib, Placement, Workload};
use nsim::util::args::Args;
use nsim::util::json::{write_file, Json};
use nsim::util::table::Table;

fn main() {
    let args = Args::parse();
    let w = Workload::microcircuit_full();
    let c = Calib::default();

    let mut out = Json::obj();
    for placement in [Placement::Sequential, Placement::Distant] {
        let res = strong_scaling(&w, &c, placement, None);
        println!("\n== {} placing ==", placement.name());
        let mut t = Table::new([
            "threads", "RTF", "speedup", "eff", "upd%", "del%", "comm%", "L3/thr[MB]",
        ]);
        let r1 = res.at(1).map(|r| r.pred.rtf).unwrap_or(f64::NAN);
        for r in &res.rows {
            let show = matches!(
                r.threads,
                1 | 2 | 4 | 8 | 16 | 24 | 32 | 33 | 34 | 40 | 48 | 56 | 64 | 96 | 128 | 256
            );
            if !show {
                continue;
            }
            let f = r.pred.fractions();
            let speedup = r1 / r.pred.rtf;
            t.add_row([
                r.threads.to_string(),
                format!("{:.3}", r.pred.rtf),
                format!("{:.1}", speedup),
                format!("{:.2}", speedup / r.threads as f64),
                format!("{:.0}", f[0] * 100.0),
                format!("{:.0}", f[1] * 100.0),
                format!("{:.1}", f[2] * 100.0),
                format!("{:.1}", 16.0 / occupancy_estimate(placement, r.threads)),
            ]);
        }
        t.print();
        match res.first_subrealtime() {
            Some(t) => println!("sub-realtime from {t} threads; best RTF {:.3}", res.best_rtf()),
            None => println!("never sub-realtime"),
        }
        out.set(placement.name(), res.to_json());
    }

    println!("\npaper features checked:");
    let seq = strong_scaling(&w, &c, Placement::Sequential, None);
    let dist = strong_scaling(&w, &c, Placement::Distant, None);
    let r32 = seq.at(32).unwrap().pred.rtf;
    let r64 = seq.at(64).unwrap().pred.rtf;
    println!(
        "  sequential super-linear 32→64: speedup {:.2}× for 2× threads",
        r32 / r64
    );
    println!(
        "  distant jump at 33: RTF {:.3} → {:.3}",
        dist.at(32).unwrap().pred.rtf,
        dist.at(33).unwrap().pred.rtf
    );
    println!(
        "  full node (seq-128): RTF {:.3} (paper 0.70) — {}",
        seq.at(128).unwrap().pred.rtf,
        if seq.at(128).unwrap().pred.rtf < 1.0 {
            "SUB-REALTIME"
        } else {
            "not sub-realtime"
        }
    );
    println!(
        "  two nodes (seq-256): RTF {:.3} (paper 0.59) — {:.2}× faster than realtime",
        seq.at(256).unwrap().pred.rtf,
        1.0 / seq.at(256).unwrap().pred.rtf
    );

    if let Some(path) = args.get("json") {
        write_file(path, &out).expect("write json");
        println!("\nwrote {path}");
    }
}

/// Rough max-occupancy of any CCX for display (threads per 16 MB slice).
fn occupancy_estimate(p: Placement, threads: usize) -> f64 {
    use nsim::hw::cachesim::CacheShares;
    use nsim::hw::Machine;
    let nodes = threads.div_ceil(128).max(1);
    let m = Machine::epyc_rome_7702(nodes);
    let shares = CacheShares::for_cores(&m, &p.cores(&m, threads));
    16.0 * 1024.0 * 1024.0 / shares.min_share() // = max occupancy
}
