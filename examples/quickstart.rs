//! Quickstart: build a down-scaled cortical microcircuit, simulate one
//! second of biological time, and print per-population firing rates plus
//! the phase breakdown of the simulation cycle.
//!
//! ```bash
//! cargo run --release --example quickstart -- --scale 0.1 --t-model 1000
//! ```

use nsim::engine::{Decomposition, SimConfig, Simulator};
use nsim::network::microcircuit::{microcircuit, MicrocircuitConfig, FULL_MEAN_RATES, POP_NAMES};
use nsim::network::build;
use nsim::stats;
use nsim::util::args::Args;
use nsim::util::table::{fmt_count, Align, Table};
use nsim::util::timer::Phase;

fn main() {
    let args = Args::parse();
    let scale = args.get_f64("scale", 0.1);
    let t_model_ms = args.get_f64("t-model", 1000.0);
    let t_presim_ms = args.get_f64("t-presim", 100.0);
    let seed = args.get_u64("seed", 55_374);
    let threads = args.get_usize("threads", 1);

    println!("== nsim quickstart: Potjans–Diesmann microcircuit ==");
    let cfg = MicrocircuitConfig {
        scale,
        seed,
        ..Default::default()
    };
    println!(
        "scale {scale} → {} neurons; building network …",
        fmt_count(cfg.n_neurons() as u64)
    );
    let t0 = std::time::Instant::now();
    let spec = microcircuit(&cfg);
    let net = build(&spec, Decomposition::new(1, threads.max(1)));
    println!(
        "built {} synapses in {:.2} s ({:.2} GB connection memory)",
        fmt_count(net.n_synapses),
        t0.elapsed().as_secs_f64(),
        net.connection_memory_bytes() as f64 / 1e9
    );

    let mut sim = Simulator::new(
        net,
        SimConfig {
            record_spikes: true,
            os_threads: threads,
            pipelined: true,
            adaptive: true,
            vectorize: true,
        },
    );
    // discard the (already short, thanks to optimized initial conditions)
    // transient, as the paper does
    if t_presim_ms > 0.0 {
        sim.simulate(t_presim_ms);
    }
    let res = sim.simulate(t_model_ms);

    println!(
        "\nsimulated {:.1} ms of model time in {:.2} s wall — engine-RTF {:.2}",
        res.t_model_ms, res.wall_s, res.rtf
    );
    println!(
        "spikes: {}   synaptic events: {}   poisson events: {}",
        fmt_count(res.counters.spikes_emitted),
        fmt_count(res.counters.syn_events_delivered),
        fmt_count(res.counters.poisson_events),
    );

    // per-population rates vs. the reference values
    let rates = stats::population_rates(&sim.net.spec, &res.spikes, res.t_model_ms);
    let cvs = stats::population_cv_isi(&sim.net.spec, &res.spikes);
    let mut t = Table::new(["population", "rate [Hz]", "ref [Hz]", "CV ISI"]).align(0, Align::Left);
    for p in 0..8 {
        t.add_row([
            POP_NAMES[p].to_string(),
            format!("{:.2}", rates[p]),
            format!("{:.2}", FULL_MEAN_RATES[p]),
            if cvs[p].is_nan() {
                "-".into()
            } else {
                format!("{:.2}", cvs[p])
            },
        ]);
    }
    println!();
    t.print();

    // phase breakdown (the quantities of Fig 1b, bottom)
    let fr = res.timers.fractions();
    println!("\nphase fractions of wall-clock time:");
    for (i, ph) in Phase::ALL.iter().enumerate() {
        println!("  {:>12}: {:5.1} %", ph.name(), fr[i] * 100.0);
    }
}
