//! Calibration fit: coarse grid + coordinate-descent refinement of the
//! execution-model constants against the paper's anchor table, followed
//! by an exact 3-point solve of the power constants. Prints the best
//! constants; they are frozen into `Calib::default()` /
//! `PowerCalib::default()` (EXPERIMENTS.md §Calibration records the run).

use nsim::hw::calib::anchors;
use nsim::hw::{predict, Calib, HwConfig, Machine, Placement, Prediction, Workload};

struct Anchors {
    seq1: f64,
    seq32: f64,
    seq64: f64,
    seq128: f64,
    seq256: f64,
    dist64: f64,
    dist128: f64,
    llc_seq64: f64,
    llc_dist64: f64,
}

fn eval(c: &Calib, w: &Workload) -> (f64, Anchors) {
    let m1 = Machine::epyc_rome_7702(1);
    let m2 = Machine::epyc_rome_7702(2);
    let seq = |t| predict(w, &HwConfig::new(m1, Placement::Sequential, t), c);
    let dist = |t| predict(w, &HwConfig::new(m1, Placement::Distant, t), c);
    let p: [Prediction; 8] = [
        seq(1),
        seq(32),
        seq(64),
        seq(128),
        predict(w, &HwConfig::new(m2, Placement::Sequential, 256), c),
        dist(64),
        dist(128),
        dist(33),
    ];
    let a = Anchors {
        seq1: p[0].rtf,
        seq32: p[1].rtf,
        seq64: p[2].rtf,
        seq128: p[3].rtf,
        seq256: p[4].rtf,
        dist64: p[5].rtf,
        dist128: p[6].rtf,
        llc_seq64: p[2].llc_miss,
        llc_dist64: p[5].llc_miss,
    };
    // weighted squared log-ratio error
    let e = |model: f64, target: f64, wgt: f64| -> f64 {
        let r = (model / target).ln();
        wgt * r * r
    };
    let mut err = 0.0;
    err += e(a.seq1, anchors::RTF_SEQ_1, 1.0);
    err += e(a.seq32, anchors::RTF_SEQ_1 / 32.0, 1.0); // linear to 32
    err += e(a.seq64, 1.05, 1.0);
    err += e(a.seq128, anchors::RTF_SEQ_128, 3.0);
    err += e(a.seq256, anchors::RTF_SEQ_256, 2.0);
    err += e(a.dist64, 0.95, 2.0);
    err += e(a.llc_seq64, anchors::LLC_MISS_SEQ_64, 2.0);
    err += e(a.llc_dist64, anchors::LLC_MISS_DIST_64, 2.0);
    // soft shape targets
    err += e(a.dist128 / a.seq128, 1.07, 1.0); // distant slightly worse at 128
    err += e(p[7].rtf / p[1].rtf, 1.10, 0.5); // jump at 33
    (err, a)
}

fn main() {
    let w = Workload::microcircuit_full();
    let mut best = Calib::default();
    let (mut best_err, _) = eval(&best, &w);
    println!("start err {best_err:.4}");

    // coordinate descent over the key constants
    let steps: &[(&str, f64)] = &[
        ("c_update_ns", 0.5),
        ("c_deliver_ns", 0.5),
        ("state_bytes", 200.0),
        ("ring_bytes", 200.0),
        ("kappa_update", 0.1),
        ("kappa_deliver", 0.1),
        ("m_floor_update", 0.01),
        ("m_floor_deliver", 0.01),
        ("m_ceil_update", 0.02),
        ("m_ceil_deliver", 0.02),
        ("contention", 0.01),
        ("numa", 0.02),
    ];
    for sweep in 0..60 {
        let mut improved = false;
        for &(param, step) in steps {
            for dir in [-1.0, 1.0] {
                let mut c = best;
                match param {
                    "c_update_ns" => c.c_update_ns += dir * step,
                    "c_deliver_ns" => c.c_deliver_ns += dir * step,
                    "state_bytes" => c.state_bytes_per_neuron += dir * step,
                    "ring_bytes" => c.ring_bytes_per_neuron += dir * step,
                    "kappa_update" => c.kappa_update += dir * step,
                    "kappa_deliver" => c.kappa_deliver += dir * step,
                    "m_floor_update" => c.m_floor_update += dir * step,
                    "m_floor_deliver" => c.m_floor_deliver += dir * step,
                    "m_ceil_update" => c.m_ceil_update += dir * step,
                    "m_ceil_deliver" => c.m_ceil_deliver += dir * step,
                    "contention" => c.contention += dir * step,
                    "numa" => c.numa_span_factor += dir * step,
                    _ => unreachable!(),
                }
                // sanity bounds
                if c.c_update_ns < 2.0
                    || c.c_deliver_ns < 2.0
                    || c.state_bytes_per_neuron < 500.0
                    || c.ring_bytes_per_neuron < 200.0
                    || c.kappa_update < 0.5
                    || c.kappa_deliver < 0.5
                    || c.m_floor_update < 0.01
                    || c.m_floor_deliver < 0.01
                    || c.m_ceil_update <= c.m_floor_update
                    || c.m_ceil_deliver <= c.m_floor_deliver
                    || c.m_ceil_update > 0.95
                    || c.m_ceil_deliver > 0.95
                    || c.contention < 0.0
                    || c.contention > 0.6
                    || c.numa_span_factor < 1.0
                    || c.numa_span_factor > 1.8
                {
                    continue;
                }
                let (err, _) = eval(&c, &w);
                if err < best_err {
                    best_err = err;
                    best = c;
                    improved = true;
                }
            }
        }
        if !improved {
            println!("converged after sweep {sweep}");
            break;
        }
    }

    let (err, a) = eval(&best, &w);
    println!("final err {err:.4}");
    println!("{best:#?}");
    println!("\nanchors (model vs paper):");
    println!("  seq-1    {:7.2} vs {:.2}", a.seq1, anchors::RTF_SEQ_1);
    println!("  seq-32   {:7.2} vs {:.2}", a.seq32, anchors::RTF_SEQ_1 / 32.0);
    println!("  seq-64   {:7.2} vs 1.05", a.seq64);
    println!("  seq-128  {:7.3} vs {:.2}", a.seq128, anchors::RTF_SEQ_128);
    println!("  seq-256  {:7.3} vs {:.2}", a.seq256, anchors::RTF_SEQ_256);
    println!("  dist-64  {:7.3} vs 0.95", a.dist64);
    println!("  dist-128 {:7.3} vs ~{:.2}", a.dist128, a.seq128 * 1.07);
    println!("  llc seq-64  {:5.3} vs {:.2}", a.llc_seq64, anchors::LLC_MISS_SEQ_64);
    println!("  llc dist-64 {:5.3} vs {:.2}", a.llc_dist64, anchors::LLC_MISS_DIST_64);

    // ---- power: solve p_uncore, p_static, p_dyn from the 3 measured
    // configurations exactly (3×3 linear system) -------------------------
    let m1 = Machine::epyc_rome_7702(1);
    let seq64 = predict(&w, &HwConfig::new(m1, Placement::Sequential, 64), &best);
    let dist64 = predict(&w, &HwConfig::new(m1, Placement::Distant, 64), &best);
    let seq128 = predict(&w, &HwConfig::new(m1, Placement::Sequential, 128), &best);
    let x = |p: &Prediction| (1.0 - p.llc_miss).powi(3) * p.clock_scale * p.clock_scale;
    // rows: [sockets, cores, cores*x] · [p_uncore, p_static, p_dyn] = P_extra
    let rows = [
        (1.0, 64.0, 64.0 * x(&seq64), anchors::POWER_SEQ_64_KW * 1000.0),
        (2.0, 64.0, 64.0 * x(&dist64), anchors::POWER_DIST_64_KW * 1000.0),
        (2.0, 128.0, 128.0 * x(&seq128), anchors::POWER_SEQ_128_KW * 1000.0),
    ];
    // Cramer's rule
    let det3 = |m: [[f64; 3]; 3]| -> f64 {
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    };
    let a3 = [
        [rows[0].0, rows[0].1, rows[0].2],
        [rows[1].0, rows[1].1, rows[1].2],
        [rows[2].0, rows[2].1, rows[2].2],
    ];
    let b3 = [rows[0].3, rows[1].3, rows[2].3];
    let d = det3(a3);
    let mut sol = [0.0; 3];
    for k in 0..3 {
        let mut mk = a3;
        for r in 0..3 {
            mk[r][k] = b3[r];
        }
        sol[k] = det3(mk) / d;
    }
    println!(
        "\npower solve: p_uncore {:.1} W, p_core_static {:.2} W, p_core_dyn {:.2} W",
        sol[0], sol[1], sol[2]
    );
    println!(
        "x factors: seq64 {:.3} dist64 {:.3} seq128 {:.3}",
        x(&seq64),
        x(&dist64),
        x(&seq128)
    );
}
