//! Print the hardware-model predictions against every paper anchor —
//! the calibration report referenced by EXPERIMENTS.md §Calibration.

use nsim::hw::calib::anchors;
use nsim::hw::{node_power_w, predict, Calib, HwConfig, Machine, Placement, PowerCalib, Workload};
use nsim::util::table::{Align, Table};

fn main() {
    let w = Workload::microcircuit_full();
    let c = Calib::default();
    let pc = PowerCalib::default();
    let m1 = Machine::epyc_rome_7702(1);
    let m2 = Machine::epyc_rome_7702(2);

    let seq = |t: usize| predict(&w, &HwConfig::new(m1, Placement::Sequential, t), &c);
    let dist = |t: usize| predict(&w, &HwConfig::new(m1, Placement::Distant, t), &c);

    let p1 = seq(1);
    let p32 = seq(32);
    let p64 = seq(64);
    let p128 = seq(128);
    let p256 = predict(&w, &HwConfig::new(m2, Placement::Sequential, 256), &c);
    let d32 = dist(32);
    let d33 = dist(33);
    let d64 = dist(64);
    let d128 = dist(128);

    let mut t = Table::new(["anchor", "paper", "model", "ratio"]).align(0, Align::Left);
    let mut row = |name: &str, paper: f64, model: f64| {
        t.add_row([
            name.to_string(),
            format!("{paper:.3}"),
            format!("{model:.3}"),
            format!("{:.2}", model / paper),
        ]);
    };
    row("RTF seq-1", anchors::RTF_SEQ_1, p1.rtf);
    row("RTF seq-32 (linear→2.72)", anchors::RTF_SEQ_1 / 32.0, p32.rtf);
    row("RTF seq-64 (~1.05)", 1.05, p64.rtf);
    row("RTF seq-128", anchors::RTF_SEQ_128, p128.rtf);
    row("RTF seq-256 (2 nodes)", anchors::RTF_SEQ_256, p256.rtf);
    row("RTF dist-64 (<1)", 0.95, d64.rtf);
    row("RTF dist-128 (>seq-128)", 0.85, d128.rtf);
    row("dist jump 32→33 (ratio>1)", 1.08, d33.rtf / d32.rtf);
    row("LLC miss seq-64", anchors::LLC_MISS_SEQ_64, p64.llc_miss);
    row("LLC miss dist-64", anchors::LLC_MISS_DIST_64, d64.llc_miss);

    // power above baseline [kW]
    let pw = |pred: &nsim::hw::Prediction, cores: usize, sockets: usize| {
        (node_power_w(&m1, pred, &pc, cores, sockets) - pc.p_base) / 1000.0
    };
    row(
        "P seq-64 [kW]",
        anchors::POWER_SEQ_64_KW,
        pw(&p64, 64, 1),
    );
    row(
        "P dist-64 [kW]",
        anchors::POWER_DIST_64_KW,
        pw(&d64, 64, 2),
    );
    row(
        "P seq-128 [kW]",
        anchors::POWER_SEQ_128_KW,
        pw(&p128, 128, 2),
    );

    // energy per synaptic event (node power × RTF / events per model-s)
    let e128 = (node_power_w(&m1, &p128, &pc, 128, 2)) * p128.rtf / w.syn_events_per_s * 1e6;
    let e256 = (2.0 * node_power_w(&m1, &p256, &pc, 128, 2)) * p256.rtf / w.syn_events_per_s * 1e6;
    row("E/event 128 [µJ]", anchors::E_SYN_EVENT_128_UJ, e128);
    row("E/event 256 [µJ]", anchors::E_SYN_EVENT_256_UJ, e256);
    t.print();

    println!("\nphase fractions (update/deliver/comm/other):");
    for (name, p) in [
        ("seq-1", &p1),
        ("seq-64", &p64),
        ("seq-128", &p128),
        ("seq-256", &p256),
        ("dist-64", &d64),
        ("dist-128", &d128),
    ] {
        let f = p.fractions();
        println!(
            "  {name:>9}: {:.2} / {:.2} / {:.3} / {:.3}   util {:.2} clock {:.2} miss_u {:.2} miss_d {:.2}",
            f[0], f[1], f[2], f[3], p.util, p.clock_scale, p.miss_update, p.miss_deliver
        );
    }

    // full curves for eyeballing monotonicity / superlinearity
    println!("\nseq speedup vs threads (RTF1/RTF_t/t):");
    for t in [1, 2, 4, 8, 16, 24, 32, 40, 48, 56, 64] {
        let p = seq(t);
        println!("  t={t:3}  RTF {:7.3}  eff {:.2}", p.rtf, p1.rtf / p.rtf / t as f64);
    }
    println!("dist:");
    for t in [1, 8, 16, 24, 32, 33, 40, 48, 64, 96, 128] {
        let p = dist(t);
        println!("  t={t:3}  RTF {:7.3}  eff {:.2}", p.rtf, p1.rtf / p.rtf / t as f64);
    }
}
